"""Publisher: the control loop that turns training-plane checkpoint
rows into promoted serving versions.

Subscribes to the checkpoint DB's listener API (no polling of
``wait_for``): every ``kind="module"`` row — one per applied outer
update, written by the sharded executors — wakes the publisher.  When
every module of the partition has applied outer phase ``t`` (the phase
is *complete*), the publisher cuts a candidate manifest from the latest
row per module, canary-gates it against the serving version on the
shadow trace, and promotes it on pass.  An optional bake gate re-scores
the freshly promoted version on a second, disjoint shadow trace and
rolls back automatically on regression; rejected or rolled-back
compositions are quarantined so a bad version is never re-promoted.

The cycle itself is synchronous and cheap when there is nothing to do
(``publish_cycle``), which keeps tests deterministic; ``start()`` wraps
it in a daemon thread driven by the DB listener for live deployments
(examples/train_and_serve.py).
"""
from __future__ import annotations

import json
import os
import threading

from repro.obs import as_telemetry

from .manifest import Manifest


class Publisher:
    def __init__(self, db, registry, *, gate=None, bake_gate=None,
                 auto_rollback: bool = True, telemetry=None):
        self.db = db
        self.registry = registry
        self.gate = gate
        self.bake_gate = bake_gate
        self.auto_rollback = auto_rollback
        self.tel = as_telemetry(telemetry)
        self.published = 0
        self.rejected = 0
        self.rollbacks = 0
        self.cycle_errors = 0
        self.last_error: Exception | None = None
        # signatures never to re-promote — persisted in the registry
        # root so a restarted publisher does not re-promote a version a
        # previous process rejected or auto-rolled-back
        self._quarantine_file = os.path.join(registry.root,
                                             "QUARANTINE.json")
        self._quarantined: set = self._load_quarantine()
        self._event = threading.Event()
        self._stop = threading.Event()
        self._thread = None
        self._cycle_lock = threading.Lock()
        # resume: don't re-cut a phase an earlier process already
        # published.  Manifests record the completed phase they were
        # cut at (cut_phase); with staggered fragments the ref row
        # phases can run *ahead* of it (the newest row per module is
        # whichever fragment applied last), so min-over-refs — the
        # pre-fragment fallback — would overshoot and skip the next
        # completed phase after a restart.
        latest = registry.latest_manifest()
        if latest is None:
            self._last_cut_phase = -1
        else:
            cut = (latest.cut_phase if latest.cut_phase >= 0 else
                   min((r.phase for r in latest.refs), default=-1))
            # a cut that was never promoted (the process died between
            # register and promote — the chaos window) must not be
            # treated as published: back off one phase so the first
            # cycle re-cuts it (register() dedupes to the same
            # version) and the retry promotes instead of stranding
            # the candidate forever.  Quarantined cuts (rejected or
            # auto-rolled-back by a previous process; the quarantine
            # is persisted) are handled, not stranded — no backoff.
            handled = (latest.version == registry.serving_version
                       or latest.version in registry.promotion_history
                       or latest.signature in self._quarantined)
            self._last_cut_phase = cut if handled else cut - 1
        db.add_listener(self._on_row)

    # -- quarantine persistence ----------------------------------------
    def _load_quarantine(self) -> set:
        try:
            with open(self._quarantine_file) as f:
                return {tuple(sig) for sig in json.load(f)}
        except (OSError, ValueError):
            return set()

    def _quarantine(self, signature) -> None:
        self._quarantined.add(signature)
        tmp = self._quarantine_file + ".tmp"
        with open(tmp, "w") as f:
            json.dump([list(s) for s in sorted(self._quarantined)], f)
        os.replace(tmp, self._quarantine_file)

    # -- event plumbing ------------------------------------------------
    def _on_row(self, row) -> None:
        if row.kind == "module":
            self._event.set()

    def close(self) -> None:
        self._stop.set()
        self._event.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        self.db.remove_listener(self._on_row)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- bootstrap -----------------------------------------------------
    def bootstrap(self) -> Manifest:
        """Ensure a serving version exists before any outer update has
        landed: register (and promote) the base-template composition."""
        m = self.registry.register(note="bootstrap: base initialization")
        if self.registry.serving_version is None:
            self.registry.promote(m.version)
        return m

    # -- candidate detection -------------------------------------------
    def _scan(self):
        """(completed phase, latest module row per id).  Rows are in
        commit order, so the last row per module is its newest.

        With streaming fragment-wise sync a module's update for phase t
        lands as one *slice* row per fragment window plus one
        params-only full row (``extra["full"]``) when the phase
        completes; a candidate is cut only at *fragment-complete*
        versions — a module counts phase t done once every one of its
        fragments (``num_fragments`` rides on each row) has applied
        phase >= t, so a half-synced module can never leak into a
        serving manifest.  Only full rows become manifest payloads:
        slice rows carry a single fragment's leaves and cannot
        materialize a module (K=1 rows are full by construction)."""
        latest: dict = {}
        frag_phase: dict = {}
        frag_expect: dict = {}
        for r in self.db.rows(kind="module"):
            mid = (r.level, r.expert)
            if r.extra.get("full"):
                latest[mid] = r     # completeness tracked via slices
                continue
            fid = r.fragment if r.fragment >= 0 else 0
            ph = int(r.extra.get("frag_phase", r.phase))
            cur = frag_phase.setdefault(mid, {})
            cur[fid] = max(cur.get(fid, -1), ph)
            frag_expect[mid] = int(r.extra.get("num_fragments", 1))
            if frag_expect[mid] == 1:
                latest[mid] = r
        completed = -1
        for mid in self.registry.module_ids:
            frags = frag_phase.get(mid)
            if frags is None or len(frags) < frag_expect.get(mid, 1):
                return -1, latest          # a fragment never applied
            mod_done = min(frags.values())
            completed = mod_done if completed < 0 else min(completed,
                                                           mod_done)
        return completed, latest

    def completed_phase(self) -> int:
        """Highest outer phase applied by every fragment of *every*
        module (-1 if any fragment has no applied update yet)."""
        return self._scan()[0]

    def poll(self) -> Manifest | None:
        """Cut a candidate manifest if a new outer phase completed."""
        completed, latest = self._scan()
        if completed <= self._last_cut_phase:
            return None
        m = self.registry.register(latest,
                                   note=f"outer phase {completed} complete",
                                   cut_phase=completed)
        self._last_cut_phase = completed
        return m

    # -- the deployment cycle ------------------------------------------
    def publish_cycle(self) -> dict:
        """One full cycle: detect -> cut -> canary -> promote (or
        reject) -> bake -> rollback on regression."""
        try:
            with self._cycle_lock:
                out = {"cut": None, "promoted": None, "rejected": None,
                       "rolled_back": None, "report": None}
                prev_cut = self._last_cut_phase
                m = self.poll()
                if m is None:
                    return out
                try:
                    with self.tel.span("deploy.cycle",
                                       version=m.version) as sp:
                        out = self._cycle_body(out, m)
                        sp.set(promoted=out["promoted"],
                               rejected=out["rejected"],
                               rolled_back=out["rolled_back"])
                    return out
                except BaseException:
                    # crashed mid-cycle (gate error, promote died
                    # before the pointer replace): rewind the cut
                    # bookkeeping so the next cycle re-cuts this phase
                    # — register() dedupes to the same version, so the
                    # retry promotes instead of losing the candidate
                    # until the next phase completes
                    self._last_cut_phase = prev_cut
                    raise
        finally:
            # trace safe point: outside _cycle_lock (the flush does IO)
            self.tel.flush()

    def _cycle_body(self, out: dict, m: Manifest) -> dict:
        out["cut"] = m.version
        if m.signature in self._quarantined:
            out["rejected"] = m.version
            self.rejected += 1
            return out
        prev = self.registry.serving_version
        if prev is not None and prev == m.version:
            return out
        if self.gate is not None and prev is not None:
            with self.tel.span("deploy.canary", version=m.version,
                               stage="canary") as sp:
                report = self.gate.evaluate(
                    self.registry.materialize(m.version),
                    self.registry.serving_paths())
                sp.set(passed=bool(report.passed))
            out["report"] = report
            if not report.passed:
                self._quarantine(m.signature)
                self.rejected += 1
                out["rejected"] = m.version
                self.tel.instant("deploy.reject", version=m.version)
                return out
        self.registry.promote(m.version)
        self.published += 1
        out["promoted"] = m.version
        self.tel.instant("deploy.promote", version=m.version)
        if self.bake_gate is not None and prev is not None:
            with self.tel.span("deploy.canary", version=m.version,
                               stage="bake") as sp:
                bake = self.bake_gate.evaluate(
                    self.registry.serving_paths(),
                    self.registry.materialize(prev))
                sp.set(passed=bool(bake.passed))
            out["report"] = bake
            if not bake.passed and self.auto_rollback:
                self._quarantine(m.signature)
                self.registry.rollback()
                self.rollbacks += 1
                out["rolled_back"] = m.version
                out["promoted"] = None
                self.tel.instant("deploy.rollback", version=m.version)
        return out

    # -- background mode -----------------------------------------------
    def start(self, period: float = 0.5) -> "Publisher":
        """Run publish cycles on a daemon thread, woken by module-row
        writes (and at least every ``period`` seconds as a fallback)."""
        if self._thread is not None:
            return self
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                self._event.wait(timeout=period)
                self._event.clear()
                if self._stop.is_set():
                    return
                try:
                    self.publish_cycle()
                except Exception as e:  # noqa: BLE001
                    # an always-on publisher must survive transient
                    # failures (disk full, a row GC'd mid-cut, gate
                    # scoring errors): a dead daemon would leave
                    # engines silently serving stale weights forever
                    self.cycle_errors += 1
                    self.last_error = e

        self._thread = threading.Thread(target=loop, name="publisher",
                                        daemon=True)
        self._thread.start()
        return self
