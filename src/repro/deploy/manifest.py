"""Deployment manifests: immutable descriptions of one servable model
version.

A DiPaCo "version" is not one weight blob — it is a *composition*: one
checkpoint row per module (level, expert) plus the shared leaves
(paper §2.3: a path is a choice of module per level; §2.4/App. A: each
module checkpoints independently and continuously).  A manifest pins
that composition: for every module id it records the content digest of
the exact parameter payload, so

 * two manifests that share a module reference share its bytes (shared
   modules are materialized once and reused by every path through
   them), and
 * promote/rollback are exact — a version is its digest tuple, nothing
   ambient.

``file=None`` marks a module still at its base initialization (no outer
update has been applied yet); the registry materializes those from its
construction-time template, whose digest is recorded all the same.
"""
from __future__ import annotations

import hashlib
import json
import time
from dataclasses import asdict, dataclass, field

import jax
import numpy as np

# module id of the shared-leaves executor (embeddings / final norm)
SHARED_ID = (-1, -1)


def file_digest(path: str) -> str:
    """Content hash of a checkpoint file (identity of a module payload)."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def tree_digest(tree) -> str:
    """Content hash of a parameter pytree (used for base-init modules,
    which have no checkpoint file to hash)."""
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(leaf)
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


@dataclass(frozen=True)
class ModuleRef:
    """One module's pinned payload inside a manifest."""
    level: int
    expert: int
    digest: str
    file: str | None = None      # None = base initialization (template)
    phase: int = -1              # outer phase of the applied update
    step: int = -1               # executor update counter

    @property
    def module_id(self) -> tuple:
        return (self.level, self.expert)


@dataclass(frozen=True)
class Manifest:
    """A servable version: module-id -> pinned payload."""
    version: int
    refs: tuple                  # tuple[ModuleRef, ...]
    parent: int = -1             # version this candidate was cut from
    created_at: float = field(default_factory=time.time)
    note: str = ""
    # the completed outer phase this candidate was cut at.  With
    # staggered fragments a ref's row phase can run *ahead* of the cut
    # phase (the newest row per module is whichever fragment applied
    # last), so publisher resume bookkeeping needs the cut phase
    # recorded explicitly; -1 = pre-fragment manifest (falls back to
    # min over ref phases).  Not part of the signature: the identity of
    # a version is its composition, not when it was cut.
    cut_phase: int = -1

    def __post_init__(self):
        ids = [r.module_id for r in self.refs]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate module ids in manifest: {ids}")

    @property
    def by_id(self) -> dict:
        return {r.module_id: r for r in self.refs}

    @property
    def signature(self) -> tuple:
        """Digest tuple in module-id order — the version's identity."""
        return tuple(r.digest for r in
                     sorted(self.refs, key=lambda r: r.module_id))

    def to_json(self) -> str:
        return json.dumps({
            "version": self.version, "parent": self.parent,
            "created_at": self.created_at, "note": self.note,
            "cut_phase": self.cut_phase,
            "refs": [asdict(r) for r in self.refs]}, indent=2)

    @classmethod
    def from_json(cls, text: str) -> "Manifest":
        d = json.loads(text)
        return cls(version=d["version"], parent=d.get("parent", -1),
                   created_at=d.get("created_at", 0.0),
                   note=d.get("note", ""),
                   cut_phase=d.get("cut_phase", -1),
                   refs=tuple(ModuleRef(**r) for r in d["refs"]))
