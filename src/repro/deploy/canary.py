"""Canary gate: score a candidate version against the serving version
on a held-out shadow trace before (and after) promotion.

Scoring is teacher-forced and deterministic: each shadow document is
assigned to one path (round-robin by default, or the deployment's
router via ``route_fn``) and scored with a single forward pass —

 * **perplexity** of the candidate vs the serving version on the same
   documents (quality must not regress beyond ``ppl_ratio_tol``), and
 * **greedy-token agreement**: the fraction of positions where the
   candidate's argmax next-token prediction matches the serving
   version's (a cheap proxy for "how different will live outputs be";
   a training step legitimately moves some tokens, so the threshold is
   a floor, not an equality check).

The gate is pure scoring — promotion, rejection and rollback decisions
live in deploy/publisher.py.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.models.lm import lm_loss_mean


@dataclass(frozen=True)
class CanaryReport:
    ppl_candidate: float
    ppl_serving: float
    agreement: float             # greedy-token agreement vs serving
    passed: bool
    reason: str = ""


class CanaryGate:
    def __init__(self, cfg, shadow_tokens, *, route_fn=None,
                 ppl_ratio_tol: float = 1.05, min_agreement: float = 0.8):
        """shadow_tokens: (N, S) int32 held-out documents (the shadow
        trace).  route_fn: prompt -> path id; round-robin when None."""
        self.cfg = cfg
        self.shadow = np.asarray(shadow_tokens, np.int32)
        if self.shadow.ndim != 2 or not len(self.shadow):
            raise ValueError(
                f"shadow trace must be (N, S), got {self.shadow.shape}")
        self.route_fn = route_fn
        self.ppl_ratio_tol = ppl_ratio_tol
        self.min_agreement = min_agreement
        cfg_ = cfg

        @jax.jit
        def _score(params, toks):
            logits, _ = api.forward_logits(params, cfg_, {"tokens": toks})
            nll = lm_loss_mean(logits, toks, cfg_.route_prefix_len)
            return nll, jnp.argmax(logits, axis=-1)

        self._score = _score
        self._assign_cache: dict = {}
        # version-score memo keyed by the identity of the path list —
        # the registry memoizes materialized versions, so the serving
        # list is the same object across publish cycles and its shadow
        # score need not be recomputed every candidate.  Entries hold a
        # strong ref to the keyed list (id stays valid); bounded small
        # so superseded versions are not pinned in memory.
        self._score_memo: dict = {}
        self._score_memo_cap = 4

    def _assignments(self, num_paths: int) -> np.ndarray:
        a = self._assign_cache.get(num_paths)
        if a is None:
            if self.route_fn is None:
                a = np.arange(len(self.shadow)) % num_paths
            else:
                a = np.asarray([int(self.route_fn(doc))
                                for doc in self.shadow])
            self._assign_cache[num_paths] = a
        return a

    def score(self, path_params_list) -> dict:
        """Per-version score: mean NLL / perplexity over the shadow
        trace plus the greedy next-token predictions (for agreement)."""
        assign = self._assignments(len(path_params_list))
        nll_sum, n_docs = 0.0, 0
        preds = np.zeros(self.shadow.shape, np.int32)
        for p in range(len(path_params_list)):
            idx = np.nonzero(assign == p)[0]
            if not len(idx):
                continue
            nll, pred = self._score(path_params_list[p],
                                    jnp.asarray(self.shadow[idx]))
            nll_sum += float(nll) * len(idx)
            n_docs += len(idx)
            preds[idx] = np.asarray(pred)
        nll = nll_sum / max(n_docs, 1)
        with np.errstate(over="ignore"):     # inf ppl = gated regression
            ppl = float(np.exp(nll))
        return {"nll": nll, "ppl": ppl, "preds": preds}

    def _score_cached(self, path_params_list) -> dict:
        hit = self._score_memo.get(id(path_params_list))
        if hit is not None and hit[0] is path_params_list:
            return hit[1]
        s = self.score(path_params_list)
        while len(self._score_memo) >= self._score_memo_cap:
            del self._score_memo[next(iter(self._score_memo))]
        self._score_memo[id(path_params_list)] = (path_params_list, s)
        return s

    def evaluate(self, candidate_paths, serving_paths) -> CanaryReport:
        """Gate a candidate against the currently serving version."""
        cand = self._score_cached(candidate_paths)
        serv = self._score_cached(serving_paths)
        agreement = float(np.mean(cand["preds"] == serv["preds"]))
        if not np.isfinite(cand["ppl"]):
            return CanaryReport(cand["ppl"], serv["ppl"], agreement, False,
                                "candidate perplexity is not finite")
        if cand["ppl"] > serv["ppl"] * self.ppl_ratio_tol:
            return CanaryReport(
                cand["ppl"], serv["ppl"], agreement, False,
                f"perplexity regression: {cand['ppl']:.4f} > "
                f"{serv['ppl']:.4f} * {self.ppl_ratio_tol}")
        if agreement < self.min_agreement:
            return CanaryReport(
                cand["ppl"], serv["ppl"], agreement, False,
                f"greedy agreement {agreement:.3f} < {self.min_agreement}")
        return CanaryReport(cand["ppl"], serv["ppl"], agreement, True)
