"""CLI: ``python -m repro.obs {summary,export,validate} trace.jsonl``."""

from __future__ import annotations

import argparse
import json
import sys

from .perfetto import export_perfetto
from .summary import format_summary, summarize
from .trace import read_trace, validate_trace


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Inspect repro telemetry traces (JSONL).")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summary", help="analyze a trace: comm overlap, "
                       "retry storms, stragglers, swap dips")
    p.add_argument("trace")
    p.add_argument("--json", action="store_true",
                   help="emit the summary as JSON")

    p = sub.add_parser("export", help="convert to Perfetto trace_event "
                       "JSON (open at https://ui.perfetto.dev)")
    p.add_argument("trace")
    p.add_argument("-o", "--out", default=None,
                   help="output path (default: <trace>.perfetto.json)")

    p = sub.add_parser("validate", help="schema-check every record")
    p.add_argument("trace")

    args = ap.parse_args(argv)

    if args.cmd == "summary":
        records, skipped = read_trace(args.trace)
        s = summarize(records, skipped)
        print(json.dumps(s, indent=2, default=str) if args.json
              else format_summary(s))
        return 0

    if args.cmd == "export":
        out = args.out or (args.trace.rsplit(".jsonl", 1)[0]
                           + ".perfetto.json")
        n, skipped = export_perfetto(args.trace, out)
        print(f"wrote {n} trace events -> {out}"
              + (f" (skipped {skipped} torn lines)" if skipped else ""))
        return 0

    records, skipped = read_trace(args.trace)
    errors = validate_trace(records)
    for e in errors:
        print(f"error: {e}", file=sys.stderr)
    print(f"{len(records)} records, {skipped} torn lines, "
          f"{len(errors)} schema errors")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
