"""Typed metric registry: counters, gauges, histograms with labels.

Design contract (PR-8 lock discipline):

- **Hot-path recording is lock-free.**  Every metric keeps one private
  cell per recording thread (``threading.local``).  A thread's first
  touch registers its cell into the metric's shared cell list under the
  registry lock (cold path, once per thread per metric); every later
  ``inc``/``set``/``observe`` mutates only the thread-private cell —
  no lock, no contention, GIL-atomic dict ops.
- **Reads are snapshot-under-lock.**  ``MetricRegistry.snapshot()``
  merges all cells while holding the registry lock, so concurrent
  metric *creation* cannot race the read.  A cell owned by a thread
  that is mid-update may contribute a value that is one record stale;
  callers that need exact totals (e.g. ``TrainingService`` comm
  accounting) perform both the updates and the snapshot under their
  own outer lock, which makes the numbers exact.

Naming convention (documented in README "Observability"):
``plane.component.metric`` — e.g. ``train.comm.send_bytes``,
``serve.engine.ticks``, ``deploy.canary.verdicts``.
"""

from __future__ import annotations

import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricRegistry"]

# monotonically increasing stamp so Gauge.snapshot can pick the most
# recent set() across thread cells without any cross-thread ordering
_seq_lock = threading.Lock()
_seq = 0


def _next_seq():
    global _seq
    with _seq_lock:
        _seq += 1
        return _seq


def _labelkey(labels):
    return tuple(sorted(labels.items())) if labels else ()


def _labelstr(key):
    return ",".join(f"{k}={v}" for k, v in key)


def _bucket(v):
    """Power-of-two upper bound for histogram bucketing (0 for v<=0)."""
    if v <= 0:
        return 0
    n = int(math.ceil(v))
    b = 1
    while b < n:
        b <<= 1
    return b


class _Metric:
    """Shared cell plumbing: one private dict per recording thread."""

    kind = "metric"

    def __init__(self, name, registry_lock):
        self.name = name
        self._lock = registry_lock
        self._cells = []  # all thread cells; appended under self._lock
        self._tl = threading.local()

    def _cell(self):
        cell = getattr(self._tl, "cell", None)
        if cell is None:
            cell = {}
            with self._lock:  # cold path: first touch per thread
                self._cells.append(cell)
            self._tl.cell = cell
        return cell

    def reset_locked(self):
        """Clear all cells in place (caller holds the registry lock)."""
        for cell in self._cells:
            cell.clear()


class Counter(_Metric):
    """Monotonic counter.  ``inc(n, **labels)`` on the hot path."""

    kind = "counter"

    # analysis: lockfree(thread-private cell; merged under the registry lock by snapshot)
    def inc(self, n=1, **labels):
        cell = self._cell()
        key = _labelkey(labels)
        cell[key] = cell.get(key, 0) + n

    def snapshot_locked(self):
        out = {}
        for cell in self._cells:
            for key, v in list(cell.items()):
                out[key] = out.get(key, 0) + v
        return {_labelstr(k): v for k, v in sorted(out.items())}


class Gauge(_Metric):
    """Last-write-wins gauge (cross-thread order via a global stamp)."""

    kind = "gauge"

    # analysis: lockfree(thread-private cell; merged under the registry lock by snapshot)
    def set(self, value, **labels):
        self._cell()[_labelkey(labels)] = (_next_seq(), float(value))

    def snapshot_locked(self):
        out = {}
        for cell in self._cells:
            for key, stamped in list(cell.items()):
                cur = out.get(key)
                if cur is None or stamped[0] > cur[0]:
                    out[key] = stamped
        return {_labelstr(k): v for k, (_, v) in sorted(out.items())}


class Histogram(_Metric):
    """Streaming histogram: count / sum / min / max + pow2 buckets.

    ``observe(v)`` is the hot path.  The per-label state is a mutable
    list ``[count, sum, min, max, {bucket: n}]`` owned by one thread.
    """

    kind = "histogram"

    # analysis: lockfree(thread-private cell; merged under the registry lock by snapshot)
    def observe(self, value, **labels):
        cell = self._cell()
        key = _labelkey(labels)
        st = cell.get(key)
        if st is None:
            st = cell[key] = [0, 0.0, math.inf, -math.inf, {}]
        st[0] += 1
        st[1] += value
        if value < st[2]:
            st[2] = value
        if value > st[3]:
            st[3] = value
        b = _bucket(value)
        st[4][b] = st[4].get(b, 0) + 1

    def snapshot_locked(self):
        out = {}
        for cell in self._cells:
            for key, st in list(cell.items()):
                acc = out.get(key)
                if acc is None:
                    acc = out[key] = [0, 0.0, math.inf, -math.inf, {}]
                acc[0] += st[0]
                acc[1] += st[1]
                acc[2] = min(acc[2], st[2])
                acc[3] = max(acc[3], st[3])
                for b, n in list(st[4].items()):
                    acc[4][b] = acc[4].get(b, 0) + n
        return {
            _labelstr(k): {
                "count": st[0],
                "sum": st[1],
                "min": st[2] if st[0] else 0,
                "max": st[3] if st[0] else 0,
                "buckets": dict(sorted(st[4].items())),
            }
            for k, st in sorted(out.items())
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricRegistry:
    """Get-or-create metric store with consistent snapshot reads."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics = {}

    def _get(self, name, cls):
        # analysis: lockfree(dict.get is GIL-atomic; creation double-checks under the lock)
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = self._metrics[name] = cls(name, self._lock)
        if type(m) is not cls:
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}"
            )
        return m

    def counter(self, name) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self, prefix=""):
        """``{name: {"kind": ..., "values": {labelstr: value}}}``."""
        with self._lock:
            return {
                name: {"kind": m.kind, "values": m.snapshot_locked()}
                for name, m in sorted(self._metrics.items())
                if name.startswith(prefix)
            }

    def flat(self, prefix=""):
        """Flatten a snapshot to ``{name[{labels}]: number}`` for
        counter samples in the trace (histograms contribute their
        ``count``/``sum``/``max`` components)."""
        out = {}
        for name, entry in self.snapshot(prefix).items():
            for lab, v in entry["values"].items():
                base = f"{name}{{{lab}}}" if lab else name
                if entry["kind"] == "histogram":
                    out[f"{base}.count"] = v["count"]
                    out[f"{base}.sum"] = v["sum"]
                    out[f"{base}.max"] = v["max"]
                else:
                    out[base] = v
        return out

    def reset(self, prefix=""):
        """Zero matching metrics in place (benchmark warmup boundary)."""
        with self._lock:
            for name, m in self._metrics.items():
                if name.startswith(prefix):
                    m.reset_locked()
