"""Crash-safe JSONL span/event tracer.

File format — one JSON object per line, four record kinds:

- ``{"k": "hdr", "epoch": E, "pid", "tid", "wall", "mono", "meta"}``
  written once per writing process, first thing after open.  It
  anchors that process's monotonic clock (``mono``, ns) to wall time
  (``wall``, s) so the exporter can place records from different
  processes / resumed runs on one absolute timeline.  ``epoch``
  counts prior headers in the file: a resumed run appends a new
  header with ``epoch + 1`` rather than truncating history.
- ``{"k": "span", "name", "t0", "t1", "pid", "tid", "args"}`` —
  a completed duration (monotonic ns).
- ``{"k": "ev", "name", "t", "pid", "tid", "args"}`` — instant event.
- ``{"k": "ctr", "t", "pid", "tid", "values"}`` — metric sample.

Crash safety: the file is opened in unbuffered binary append mode, so
every drain is a single ``write()`` of whole lines — a ``kill -9``
leaves at most one torn trailing line, and every record before it
stays parseable.  On append-reopen the writer seals a torn tail with
a newline before writing its header.

Hot path: ``emit`` encodes the record and appends the line to a
``deque`` — GIL-atomic, no lock.  Lines reach the file on explicit
``flush()`` (service/publisher/engine call it at safe points, never
under their locks) or when the buffer crosses ``flush_every`` lines.
"""

from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["TraceWriter", "read_trace", "validate_trace"]

from collections import deque

_DEFAULT_FLUSH_EVERY = 512


class _Span:
    """Context manager recording one complete span on ``__exit__``."""

    __slots__ = ("_writer", "name", "args", "t0")

    def __init__(self, writer, name, args):
        self._writer = writer
        self.name = name
        self.args = args

    def set(self, **kv):
        self.args.update(kv)

    def __enter__(self):
        self.t0 = time.monotonic_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.args["error"] = exc_type.__name__
        self._writer.emit_span(self.name, self.t0, time.monotonic_ns(),
                               self.args)
        return False


class TraceWriter:
    def __init__(self, path, *, meta=None, fresh=False, flush_every=None):
        self.path = os.fspath(path)
        self.flush_every = (_DEFAULT_FLUSH_EVERY if flush_every is None
                            else max(1, int(flush_every)))
        self._buf = deque()
        self._io_lock = threading.Lock()
        self._closed = False
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        epoch, seal = 0, False
        if not fresh and os.path.exists(self.path):
            epoch, seal = self._scan_existing()
        mode = "wb" if fresh else "ab"
        # buffering=0: each drain is one write() of whole lines, so a
        # kill leaves at most a single torn trailing line
        self._fh = open(self.path, mode, buffering=0)
        if seal:
            self._fh.write(b"\n")  # seal a torn tail from a prior crash
        self.epoch = epoch
        hdr = {
            "k": "hdr",
            "epoch": epoch,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "wall": time.time(),
            "mono": time.monotonic_ns(),
            "meta": meta or {},
        }
        self._fh.write(json.dumps(hdr).encode() + b"\n")

    def _scan_existing(self):
        """Count prior headers; report whether the tail line is torn."""
        epochs = 0
        seal = False
        with open(self.path, "rb") as fh:
            data = fh.read()
        if data:
            seal = not data.endswith(b"\n")
            for line in data.splitlines():
                if b'"k": "hdr"' in line or b'"k":"hdr"' in line:
                    epochs += 1
        return epochs, seal

    # -- hot path ---------------------------------------------------
    # analysis: lockfree(deque.append is GIL-atomic; drained under _io_lock by flush)
    def _emit(self, rec):
        self._buf.append(json.dumps(rec).encode() + b"\n")
        if len(self._buf) >= self.flush_every:
            self.flush()

    def emit_span(self, name, t0_ns, t1_ns, args=None):
        self._emit({
            "k": "span", "name": name, "t0": t0_ns, "t1": t1_ns,
            "pid": os.getpid(), "tid": threading.get_ident(),
            "args": args or {},
        })

    def span(self, name, **args):
        return _Span(self, name, args)

    def instant(self, name, **args):
        self._emit({
            "k": "ev", "name": name, "t": time.monotonic_ns(),
            "pid": os.getpid(), "tid": threading.get_ident(),
            "args": args,
        })

    def counters(self, values):
        self._emit({
            "k": "ctr", "t": time.monotonic_ns(),
            "pid": os.getpid(), "tid": threading.get_ident(),
            "values": values,
        })

    # -- cold path --------------------------------------------------
    def flush(self):
        """Drain buffered lines to disk.  Never call while holding a
        subsystem lock — this does file IO (enforced by the LCK301
        blocking-under-lock analysis entry)."""
        lines = []
        while True:
            try:
                lines.append(self._buf.popleft())
            except IndexError:
                break
        if not lines:
            return
        with self._io_lock:
            if self._closed:
                return
            self._fh.write(b"".join(lines))

    def close(self):
        self.flush()
        with self._io_lock:
            if not self._closed:
                self._closed = True
                self._fh.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# -- readers --------------------------------------------------------

_REQUIRED = {
    "hdr": ("epoch", "pid", "wall", "mono"),
    "span": ("name", "t0", "t1", "pid", "tid"),
    "ev": ("name", "t", "pid", "tid"),
    "ctr": ("t", "pid", "tid", "values"),
}


def read_trace(path):
    """Parse a trace JSONL file.

    Returns ``(records, skipped)`` where ``skipped`` counts
    unparseable lines (torn tails from crashes).  Every complete
    record is returned even when a torn line sits mid-file (a crash
    followed by an append-resume).
    """
    records, skipped = [], 0
    with open(path, "rb") as fh:
        data = fh.read()
    for line in data.splitlines():
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            skipped += 1
            continue
        records.append(rec)
    return records, skipped


def validate_trace(records):
    """Schema-check records; returns a list of error strings."""
    errors = []
    if not records or records[0].get("k") != "hdr":
        errors.append("trace does not start with a hdr record")
    for i, rec in enumerate(records):
        kind = rec.get("k")
        req = _REQUIRED.get(kind)
        if req is None:
            errors.append(f"record {i}: unknown kind {kind!r}")
            continue
        missing = [f for f in req if f not in rec]
        if missing:
            errors.append(f"record {i} ({kind}): missing {missing}")
        if kind == "span" and not missing and rec["t1"] < rec["t0"]:
            errors.append(f"record {i} (span {rec['name']}): t1 < t0")
    return errors
