"""Trace analytics: the questions a chaos run's timeline should answer.

- **comm overlap** — per training phase, how much fragment-send wire
  time was hidden under *other shards'* inner compute (the Streaming
  DiLoCo objective: comm overlapped with compute costs nothing).
- **retry storms** — windows where transport retries cluster, with
  the shards/phases involved.
- **straggler attribution** — per-shard mean phase wall time against
  the fleet median.
- **swap dips** — serving tick latency inside engine hot-swap windows
  vs steady state.
"""

from __future__ import annotations

__all__ = ["summarize", "format_summary"]

_STORM_WINDOW_NS = 100_000_000  # 100 ms
_STORM_MIN = 3


def _spans(records, name):
    return [r for r in records
            if r.get("k") == "span" and r.get("name") == name]


def _events(records, name):
    return [r for r in records
            if r.get("k") == "ev" and r.get("name") == name]


def _overlap(a0, a1, intervals):
    """Total length of [a0, a1] covered by the union of intervals."""
    covered = 0
    cur = a0
    for b0, b1 in sorted(intervals):
        if b1 <= cur:
            continue
        if b0 >= a1:
            break
        covered += min(a1, b1) - max(cur, b0)
        cur = max(cur, b1)
        if cur >= a1:
            break
    return covered


def comm_overlap(records):
    """Per-phase % of fragment-send time overlapped with other
    shards' ``train.phase`` compute."""
    phases = {}
    for sp in _spans(records, "train.phase"):
        args = sp.get("args") or {}
        phases.setdefault(args.get("phase"), []).append(
            (args.get("shard"), sp["t0"], sp["t1"]))
    out = {}
    for sp in _spans(records, "train.fragment_send"):
        args = sp.get("args") or {}
        t, s = args.get("phase"), args.get("shard")
        total = sp["t1"] - sp["t0"]
        others = [(t0, t1) for (sh, t0, t1) in phases.get(t, ())
                  if sh != s]
        ov = _overlap(sp["t0"], sp["t1"], others)
        acc = out.setdefault(t, [0, 0])
        acc[0] += total
        acc[1] += ov
    return {
        t: {"send_ns": tot, "overlap_pct": (100.0 * ov / tot) if tot else 0.0}
        for t, (tot, ov) in sorted(out.items(), key=lambda kv: str(kv[0]))
    }


def retry_storms(records):
    """Cluster ``transport.retry`` instants into 100 ms windows."""
    retries = sorted(_events(records, "transport.retry"),
                     key=lambda r: r["t"])
    storms = []
    i = 0
    while i < len(retries):
        j = i
        while (j + 1 < len(retries)
               and retries[j + 1]["t"] - retries[i]["t"] <= _STORM_WINDOW_NS):
            j += 1
        burst = retries[i:j + 1]
        if len(burst) >= _STORM_MIN:
            shards = sorted({(b.get("args") or {}).get("shard")
                             for b in burst}, key=str)
            storms.append({
                "count": len(burst),
                "span_ms": (burst[-1]["t"] - burst[0]["t"]) / 1e6,
                "shards": shards,
            })
        i = j + 1
    return {"total_retries": len(retries), "storms": storms}


def stragglers(records):
    """Per-shard mean ``train.phase`` wall vs the fleet median."""
    per_shard = {}
    for sp in _spans(records, "train.phase"):
        s = (sp.get("args") or {}).get("shard")
        per_shard.setdefault(s, []).append(sp["t1"] - sp["t0"])
    means = {s: sum(v) / len(v) for s, v in per_shard.items() if v}
    if not means:
        return {}
    ordered = sorted(means.values())
    median = ordered[len(ordered) // 2]
    return {
        s: {
            "mean_ms": m / 1e6,
            "vs_median": (m / median) if median else 1.0,
            "straggler": median > 0 and m / median > 1.5,
        }
        for s, m in sorted(means.items(), key=lambda kv: str(kv[0]))
    }


def swap_dips(records):
    """Mean ``serve.tick`` duration inside vs outside ``serve.swap``
    windows."""
    windows = [(sp["t0"], sp["t1"]) for sp in _spans(records, "serve.swap")]
    inside, outside = [], []
    for sp in _spans(records, "serve.tick"):
        mid = (sp["t0"] + sp["t1"]) // 2
        dur = sp["t1"] - sp["t0"]
        if any(w0 <= mid <= w1 for w0, w1 in windows):
            inside.append(dur)
        else:
            outside.append(dur)
    out = {
        "swap_windows": len(windows),
        "ticks_in_swap": len(inside),
        "ticks_steady": len(outside),
    }
    if inside and outside:
        mi = sum(inside) / len(inside)
        mo = sum(outside) / len(outside)
        out["mean_tick_in_swap_us"] = mi / 1e3
        out["mean_tick_steady_us"] = mo / 1e3
        out["dip_ratio"] = mi / mo if mo else 1.0
    return out


def summarize(records, skipped=0):
    names = {}
    for r in records:
        if r.get("k") in ("span", "ev"):
            names[r["name"]] = names.get(r["name"], 0) + 1
    return {
        "records": len(records),
        "skipped_lines": skipped,
        "epochs": sum(1 for r in records if r.get("k") == "hdr"),
        "names": dict(sorted(names.items())),
        "comm_overlap": comm_overlap(records),
        "retry_storms": retry_storms(records),
        "stragglers": stragglers(records),
        "swap_dips": swap_dips(records),
    }


def format_summary(summary):
    lines = [
        f"records: {summary['records']}  "
        f"(skipped torn lines: {summary['skipped_lines']}, "
        f"epochs: {summary['epochs']})",
        "",
        "span/event counts:",
    ]
    for name, n in summary["names"].items():
        lines.append(f"  {name:<24} {n}")
    if summary["comm_overlap"]:
        lines += ["", "comm overlap (fragment-send time hidden under "
                      "other shards' compute):"]
        for t, row in summary["comm_overlap"].items():
            lines.append(f"  phase {t}: {row['overlap_pct']:5.1f}%  "
                         f"of {row['send_ns'] / 1e6:.2f} ms send time")
    rs = summary["retry_storms"]
    if rs["total_retries"]:
        lines += ["", f"transport retries: {rs['total_retries']}"]
        for storm in rs["storms"]:
            lines.append(f"  storm: {storm['count']} retries in "
                         f"{storm['span_ms']:.1f} ms "
                         f"(shards {storm['shards']})")
    if summary["stragglers"]:
        lines += ["", "straggler attribution (mean train.phase wall):"]
        for s, row in summary["stragglers"].items():
            flag = "  << straggler" if row["straggler"] else ""
            lines.append(f"  shard {s}: {row['mean_ms']:8.2f} ms  "
                         f"({row['vs_median']:.2f}x median){flag}")
    sd = summary["swap_dips"]
    if sd.get("swap_windows"):
        lines += ["", f"engine swaps: {sd['swap_windows']} windows, "
                      f"{sd['ticks_in_swap']} ticks inside"]
        if "dip_ratio" in sd:
            lines.append(
                f"  tick wall in-swap {sd['mean_tick_in_swap_us']:.1f} µs "
                f"vs steady {sd['mean_tick_steady_us']:.1f} µs "
                f"(dip ratio {sd['dip_ratio']:.2f}x)")
    return "\n".join(lines)
