"""Chrome/Perfetto ``trace_event`` JSON exporter.

Converts a repro JSONL trace into the `trace_event format
<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
understood by https://ui.perfetto.dev and ``chrome://tracing``:

- ``span``  → ``ph="X"`` complete events (``ts``/``dur`` in µs)
- ``ev``    → ``ph="i"`` instant events (thread scope)
- ``ctr``   → ``ph="C"`` counter events
- ``hdr``   → process/thread ``M`` metadata + the clock anchor used
  to map each epoch's monotonic nanoseconds onto absolute wall-clock
  microseconds, so resumed runs line up on one timeline.
"""

from __future__ import annotations

import json

from .trace import read_trace

__all__ = ["export_perfetto", "to_trace_events"]


class _Anchor:
    __slots__ = ("wall_us", "mono_ns")

    def __init__(self, hdr):
        self.wall_us = hdr["wall"] * 1e6
        self.mono_ns = hdr["mono"]

    def ts(self, mono_ns):
        return self.wall_us + (mono_ns - self.mono_ns) / 1e3


def to_trace_events(records):
    """Convert parsed JSONL records to a ``traceEvents`` list."""
    events = []
    anchors = {}  # pid -> most recent _Anchor (per epoch header)
    seen_pids = set()
    for rec in records:
        kind = rec.get("k")
        pid = rec.get("pid", 0)
        if kind == "hdr":
            anchors[pid] = anchor = _Anchor(rec)
            meta = rec.get("meta") or {}
            if pid not in seen_pids:
                seen_pids.add(pid)
                name = meta.get("suite") or meta.get("name") or "repro"
                events.append({
                    "ph": "M", "name": "process_name", "pid": pid,
                    "tid": 0, "args": {"name": f"{name} (pid {pid})"},
                })
            events.append({
                "ph": "i", "name": f"epoch {rec['epoch']}",
                "pid": pid, "tid": rec.get("tid", 0), "s": "p",
                "ts": anchor.ts(rec["mono"]), "args": meta,
            })
            continue
        anchor = anchors.get(pid)
        if anchor is None:
            continue  # records before any header for this pid
        tid = rec.get("tid", 0)
        if kind == "span":
            events.append({
                "ph": "X", "name": rec["name"], "pid": pid, "tid": tid,
                "ts": anchor.ts(rec["t0"]),
                "dur": max(0.001, (rec["t1"] - rec["t0"]) / 1e3),
                "args": rec.get("args") or {},
            })
        elif kind == "ev":
            events.append({
                "ph": "i", "name": rec["name"], "pid": pid, "tid": tid,
                "s": "t", "ts": anchor.ts(rec["t"]),
                "args": rec.get("args") or {},
            })
        elif kind == "ctr":
            ts = anchor.ts(rec["t"])
            for name, value in sorted((rec.get("values") or {}).items()):
                events.append({
                    "ph": "C", "name": name, "pid": pid, "tid": 0,
                    "ts": ts, "args": {"value": value},
                })
    return events


def export_perfetto(trace_path, out_path):
    """Read a JSONL trace and write Perfetto-loadable JSON.

    Returns ``(num_events, skipped_lines)``.
    """
    records, skipped = read_trace(trace_path)
    events = to_trace_events(records)
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    with open(out_path, "w") as fh:
        json.dump(doc, fh)
    return len(events), skipped
