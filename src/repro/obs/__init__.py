"""repro.obs — unified telemetry plane.

One nullable handle (``Telemetry``) threads through every subsystem:
the training service, transport, fleet/chaos controllers, worker
pool, deploy publisher, and serving engine all accept
``telemetry=None`` and pay nothing when it is absent (``NULL`` is a
shared no-op whose ``span`` returns a singleton context manager).

Enabled, it provides:

- ``span(name, **args)`` / ``instant(name, **args)`` — structured
  spans and events into a crash-safe JSONL trace (``trace.py``),
- a typed :class:`~repro.obs.metrics.MetricRegistry` (``.metrics``)
  with lock-free hot-path recording,
- ``sample_metrics()`` — snapshot the registry into the trace as a
  counter record,
- exporters: Chrome/Perfetto ``trace_event`` JSON (``perfetto.py``)
  and a summary CLI (``python -m repro.obs``).

Span/event name vocabulary (``plane.component``):

======================  ============================================
``train.phase``         one shard×phase inner-loop execution
``train.fragment_send`` one fragment slot shipped on the wire
``train.run``           one ``TrainingService.run`` window
``transport.ship``      mesh transport device round-trip
``transport.retry``     instant: a send attempt failed and backed off
``fleet.epoch``         instant: membership epoch commit
``fleet.chaos``         instant: chaos controller action
``pool.task``           worker-pool task execution
``pool.preempt``        instant: simulated worker preemption
``pool.restart``        instant: monitor restarted dead workers
``deploy.cycle``        one publisher publish cycle
``deploy.canary``       canary gate evaluation
``deploy.promote`` / ``deploy.reject`` / ``deploy.rollback``  instants
``serve.tick``          one continuous-batching engine step
``serve.swap``          engine hot-swap window (drain start→install)
``serve.admit``         instant: request admitted to a slot
``serve.preempt``       instant: high-priority admit evicted a
                        preemptible slot (evictee re-queues)
``serve.route``         instant: fleet front door dispatched a request
``serve.rebalance``     instant: fleet recomputed per-path replicas
======================  ============================================
"""

from __future__ import annotations

import time

from .metrics import Counter, Gauge, Histogram, MetricRegistry
from .trace import TraceWriter, read_trace, validate_trace

__all__ = [
    "NULL",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "NullTelemetry",
    "Telemetry",
    "TraceWriter",
    "as_telemetry",
    "read_trace",
    "validate_trace",
]


class _NullSpan:
    """Singleton no-op span: zero allocation on the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kv):
        pass


_NULL_SPAN = _NullSpan()


class NullTelemetry:
    """Disabled telemetry: every call is a no-op.

    ``metrics`` is ``None`` — subsystems that need a registry even
    without tracing (e.g. the service's comm accounting) create their
    own private :class:`MetricRegistry` when they see ``None``.
    """

    __slots__ = ()

    enabled = False
    metrics = None
    path = None
    trace = None

    def span(self, name, **args):
        return _NULL_SPAN

    def complete_span(self, name, t0_ns, **args):
        pass

    def instant(self, name, **args):
        pass

    def sample_metrics(self, prefix=""):
        pass

    def flush(self):
        pass

    def close(self):
        pass


NULL = NullTelemetry()


class Telemetry:
    """Live telemetry handle: a trace writer + a metric registry.

    ``path=None`` keeps the registry but drops all trace records —
    metrics-only mode with the same API.
    """

    enabled = True

    def __init__(self, path=None, *, meta=None, registry=None,
                 fresh=False, flush_every=None):
        self.path = None if path is None else str(path)
        self.metrics = registry if registry is not None else MetricRegistry()
        self.trace = (
            TraceWriter(path, meta=meta, fresh=fresh,
                        flush_every=flush_every)
            if path is not None else None
        )

    @property
    def epoch(self):
        return self.trace.epoch if self.trace is not None else 0

    def span(self, name, **args):
        if self.trace is None:
            return _NULL_SPAN
        return self.trace.span(name, **args)

    def complete_span(self, name, t0_ns, **args):
        """Record a span whose start was captured earlier (e.g. an
        engine swap window opened ticks ago)."""
        if self.trace is not None:
            self.trace.emit_span(name, t0_ns, time.monotonic_ns(), args)

    def instant(self, name, **args):
        if self.trace is not None:
            self.trace.instant(name, **args)

    def sample_metrics(self, prefix=""):
        if self.trace is not None:
            values = self.metrics.flat(prefix)
            if values:
                self.trace.counters(values)

    def flush(self):
        """Drain the trace buffer.  File IO — never call under a
        subsystem lock (LCK301 enforces this)."""
        if self.trace is not None:
            self.trace.flush()

    def close(self):
        if self.trace is not None:
            self.sample_metrics()
            self.trace.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def as_telemetry(telemetry):
    """Normalize a nullable handle: ``None`` → the shared ``NULL``."""
    return NULL if telemetry is None else telemetry
