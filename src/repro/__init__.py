"""DiPaCo reproduction.

Top-level lazy re-exports (PEP 562) of the unified training/serving
API, so ``import repro`` stays free of jax initialization and heavy
submodule imports until an attribute is actually used:

    repro.make_trainer(cfg, dcfg, dataset, backend="mesh", key=key)
    repro.EngineOptions(registry=reg, swap_policy="live")
"""
from __future__ import annotations

import importlib

_LAZY = {
    "make_trainer": "repro.training",
    "trainer_class": "repro.training",
    "Trainer": "repro.training",
    "BACKENDS": "repro.training",
    "PhaseMetrics": "repro.core.dipaco",
    "EngineOptions": "repro.serving.engine",
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    value = getattr(importlib.import_module(mod), name)
    globals()[name] = value     # cache: next access skips __getattr__
    return value


def __dir__():
    return sorted(set(list(globals()) + __all__))
