"""Unified trainer API: one protocol, one factory, four backends.

Every trainer backend exposes the same surface —

 * ``run_phase(tau=None, ...) -> PhaseMetrics``
 * ``path_params(path_id)``
 * ``resume(cfg, dcfg, dataset, *, key, ckpt_root, **kw)`` classmethod

— so launchers, examples and tests construct trainers through
``make_trainer`` instead of hand-wiring each backend's constructor:

    tr = repro.make_trainer(cfg, dcfg, dataset, backend="mesh",
                            key=key, batch_size=4)

Backends:

``"vector"``   core.dipaco.DiPaCoTrainer — in-memory stacked-worker
               simulation (Algorithm 1); no durable state.
``"barrier"``  infra.trainer.InfraDiPaCoTrainer — the round-based §3
               infrastructure pinned to a global barrier
               (max_phase_lag=0); CheckpointDB resume.
``"service"``  infra.service.TrainingService — asynchronous
               phase-pipelined service with staleness window, fragment
               streaming and delta transports; CheckpointDB resume.
``"mesh"``     launch.train.MeshStreamingTrainer — the streaming
               fragment schedule through real shard_map collectives on
               a device mesh, overlapped with inner compute;
               phase-state-file resume.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

from repro.core.dipaco import PhaseMetrics

BACKENDS = ("vector", "barrier", "service", "mesh")


@runtime_checkable
class Trainer(Protocol):
    """The surface all four backends share."""

    def run_phase(self, tau=None, **kw) -> PhaseMetrics:
        ...

    def path_params(self, path_id: int):
        ...

    @classmethod
    def resume(cls, cfg, dcfg, dataset, *, key, ckpt_root, **kw):
        ...


def trainer_class(backend: str):
    if backend == "vector":
        from repro.core.dipaco import DiPaCoTrainer
        return DiPaCoTrainer
    if backend == "barrier":
        from repro.infra.trainer import InfraDiPaCoTrainer
        return InfraDiPaCoTrainer
    if backend == "service":
        from repro.infra.service import TrainingService
        return TrainingService
    if backend == "mesh":
        from repro.launch.train import MeshStreamingTrainer
        return MeshStreamingTrainer
    raise ValueError(f"backend {backend!r} not in {BACKENDS}")


def make_trainer(cfg, dcfg, dataset, *, backend: str = "vector", key,
                 ckpt_root: str | None = None, resume: bool = False,
                 **kw) -> Trainer:
    """Construct (or resume) a trainer backend.

    ``ckpt_root`` is required for the DB-backed backends ("barrier",
    "service"), optional for "mesh" (enables phase checkpointing) and
    rejected for "vector".  Remaining kwargs go to the backend
    constructor (batch_size, peak_lr, warmup, total_steps, seed, and
    backend-specific ones like num_workers / max_phase_lag / mesh).
    """
    cls = trainer_class(backend)
    if backend == "vector":
        if ckpt_root is not None:
            raise ValueError("backend='vector' is in-memory only and "
                             "takes no ckpt_root")
        if resume:
            return cls.resume(cfg, dcfg, dataset, key=key,
                              ckpt_root=None, **kw)   # raises, on purpose
        return cls(cfg, dcfg, dataset, key=key, **kw)
    if backend in ("barrier", "service") and ckpt_root is None:
        raise ValueError(f"backend={backend!r} persists to a "
                         "CheckpointDB: pass ckpt_root=")
    if resume:
        return cls.resume(cfg, dcfg, dataset, key=key,
                          ckpt_root=ckpt_root, **kw)
    if backend == "mesh":
        return cls(cfg, dcfg, dataset, key=key, ckpt_root=ckpt_root,
                   **kw)
    return cls(cfg, dcfg, dataset, key=key, ckpt_root=ckpt_root, **kw)
