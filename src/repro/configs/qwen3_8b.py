"""Qwen3-8B [dense] — 36L d4096 32H (GQA kv=8) d_ff=12288 vocab=151936;
qk_norm, GQA.  [hf:Qwen/Qwen3-8B]"""
from repro.models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        arch_type="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        mlp_type="swiglu",
        pattern=(BlockSpec("attn", "dense"),),
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, dtype="float32", remat=False,
    )
