"""Architecture config registry.

Each ``<arch>.py`` exposes ``config() -> ModelConfig`` (the exact assigned
configuration) and ``smoke() -> ModelConfig`` (a reduced same-family
variant: <=2 pattern groups, d_model<=512, <=4 experts) used by CPU smoke
tests.  Full configs are exercised only via the AOT dry-run.
"""
from __future__ import annotations

import importlib

ASSIGNED_ARCHS = [
    "qwen3-moe-235b-a22b",
    "gemma-2b",
    "whisper-base",
    "jamba-v0.1-52b",
    "mamba2-1.3b",
    "pixtral-12b",
    "qwen3-8b",
    "qwen2-moe-a2.7b",
    "moonshot-v1-16b-a3b",
    "nemotron-4-340b",
]

PAPER_CONFIGS = ["dipaco-150m", "dipaco-dense-1b"]

ALL_CONFIGS = ASSIGNED_ARCHS + PAPER_CONFIGS


def _module(name: str):
    mod = name.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str):
    if name not in ALL_CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: {ALL_CONFIGS}")
    return _module(name).config()


def get_smoke_config(name: str):
    if name not in ALL_CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: {ALL_CONFIGS}")
    return _module(name).smoke()
