"""DiPaCo paper path model (Table 4): 12 blocks, d=896, 16 heads,
key/value size 64, vocab 32000 (SentencePiece in the paper; synthetic
corpus here)."""
from repro.models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dipaco-150m",
        arch_type="dense",
        num_layers=12,
        d_model=896,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=3584,
        vocab_size=32000,
        mlp_type="gelu",
        pattern=(BlockSpec("attn", "dense"),),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=512, dtype="float32", remat=False,
    )
