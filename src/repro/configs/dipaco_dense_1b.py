"""DiPaCo paper dense baseline (Table 4): 24 blocks, d=2048, 16 heads,
key/value size 128, vocab 32000."""
from repro.models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dipaco-dense-1b",
        arch_type="dense",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab_size=32000,
        mlp_type="gelu",
        pattern=(BlockSpec("attn", "dense"),),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=512, dtype="float32", remat=False,
    )
