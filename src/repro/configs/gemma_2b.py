"""Gemma-2B [dense] — 18L d2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256.  [arXiv:2403.08295]"""
from repro.models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        arch_type="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        mlp_type="geglu",
        pattern=(BlockSpec("attn", "dense"),),
        embed_scale=True,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=1, head_dim=64,
        d_ff=512, vocab_size=512, dtype="float32", remat=False,
    )
