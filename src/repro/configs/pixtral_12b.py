"""Pixtral-12B [vlm] — 40L d5120 32H (GQA kv=8) d_ff=14336 vocab=131072;
Pixtral-ViT STUBBED (input_specs provides patch embeddings), Mistral-Nemo
style decoder.  [hf:mistralai/Pixtral-12B-2409]"""
from repro.models.config import (BlockSpec, ModelConfig, VisionStubConfig)


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        arch_type="vlm",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        mlp_type="swiglu",
        pattern=(BlockSpec("attn", "dense"),),
        vision=VisionStubConfig(num_patches=1024, d_patch=1024),
        rope_theta=1_000_000.0,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, dtype="float32", remat=False,
        vision=VisionStubConfig(num_patches=16, d_patch=64),
    )
