"""Whisper-base [audio] — 6L enc + 6L dec, d512 8H (kv=8) d_ff=2048
vocab=51865; enc-dec, conv/mel frontend STUBBED (input_specs provides
frame embeddings).  [arXiv:2212.04356]"""
from repro.models.config import BlockSpec, EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        arch_type="audio",
        num_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=51865,
        mlp_type="gelu",
        pattern=(BlockSpec("attn", "dense"),),
        encoder=EncoderConfig(num_layers=6, num_heads=8, d_source=512,
                              source_len=1500),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512, dtype="float32", remat=False,
        encoder=EncoderConfig(num_layers=2, num_heads=4, d_source=80,
                              source_len=64),
    )
