"""Jamba-v0.1-52B [hybrid] — 32L d4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2; Mamba:attn 7:1 interleave, MoE every other
layer.  [arXiv:2403.19887]

TPU adaptation note (DESIGN.md §3): Jamba's Mamba-1 (d_state=16 selective
scan) is implemented as Mamba2/SSD with d_state=64 — the chunked SSD dual
form maps onto the MXU, whereas the Mamba-1 elementwise scan does not.
"""
from repro.models.config import BlockSpec, ModelConfig, MoEConfig, SSMConfig

# period-8 Jamba block: attention at position 4, Mamba elsewhere;
# MoE on odd positions, dense MLP on even.
_PATTERN = tuple(
    BlockSpec("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        arch_type="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        mlp_type="swiglu",
        pattern=_PATTERN,
        moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336),
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, chunk=256),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=512, vocab_size=512, dtype="float32", remat=False,
        pattern=(BlockSpec("mamba", "moe"), BlockSpec("attn", "dense")),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
        ssm=SSMConfig(d_state=32, head_dim=32, expand=2, chunk=64),
    )
