"""Nemotron-4-340B [dense] — 96L d18432 96H (GQA kv=8) d_ff=73728
vocab=256000, squared-ReLU MLP.  [arXiv:2402.16819]"""
from repro.models.config import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        arch_type="dense",
        num_layers=96,
        d_model=18432,
        num_heads=96,
        num_kv_heads=8,
        head_dim=192,
        d_ff=73728,
        vocab_size=256000,
        mlp_type="relu2",
        pattern=(BlockSpec("attn", "dense"),),
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=384, num_heads=6, num_kv_heads=2, head_dim=64,
        d_ff=768, vocab_size=512, dtype="float32", remat=False,
    )
