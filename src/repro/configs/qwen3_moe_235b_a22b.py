"""Qwen3-MoE-235B-A22B [moe] — 94L d4096 64H (GQA kv=4) moe_d_ff=1536
vocab=151936, 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B family]"""
from repro.models.config import BlockSpec, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        arch_type="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=1536,
        vocab_size=151936,
        mlp_type="swiglu",
        pattern=(BlockSpec("attn", "moe"),),
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=2, head_dim=64,
        d_ff=128, vocab_size=512, dtype="float32", remat=False,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
    )
