"""Moonshot/Moonlight-16B-A3B — 48L d2048 16H (kv=16) expert_d_ff=1408
vocab=163840, MoE 64e top-6 (+2 shared per the Moonlight card).
Assignment labels it [dense] but specifies MoE fields; we implement the
MoE per the fields (see DESIGN.md §4).  [hf:moonshotai/Moonlight-16B-A3B]"""
from repro.models.config import BlockSpec, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        arch_type="moe",
        num_layers=48,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163840,
        mlp_type="swiglu",
        pattern=(BlockSpec("attn", "moe"),),
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                      num_shared=2, d_ff_shared=2816),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=128, vocab_size=512, dtype="float32", remat=False,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      num_shared=1, d_ff_shared=128),
    )
