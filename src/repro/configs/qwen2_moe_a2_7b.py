"""Qwen2-MoE-A2.7B [moe] — 24L d2048 16H (kv=16) expert_d_ff=1408
vocab=151936, 60 routed top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.models.config import BlockSpec, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b",
        arch_type="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=151936,
        mlp_type="swiglu",
        pattern=(BlockSpec("attn", "moe"),),
        moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                      num_shared=4, d_ff_shared=5632),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, num_heads=4, num_kv_heads=4, head_dim=64,
        d_ff=128, vocab_size=512, dtype="float32", remat=False,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                      num_shared=2, d_ff_shared=256),
    )
