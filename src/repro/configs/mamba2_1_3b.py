"""Mamba2-1.3B [ssm] — 48L d2048 attn-free, ssm_state=128, SSD
(state-space duality).  [arXiv:2405.21060]"""
from repro.models.config import BlockSpec, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        arch_type="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=1,          # unused (attn-free)
        num_kv_heads=1,
        head_dim=64,
        d_ff=0,
        vocab_size=50280,
        pattern=(BlockSpec("mamba", "none"),),
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, chunk=256),
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().replace(
        num_layers=2, d_model=256, vocab_size=512, dtype="float32",
        remat=False,
        ssm=SSMConfig(d_state=32, head_dim=32, expand=2, chunk=64),
    )
