"""Telemetry plane overhead + trace validity gate.

Runs the same miniature ``TrainingService`` workload twice — tracing
disabled (the ``NULL`` handle) and tracing enabled (full span/metric
recording into a JSONL trace) — interleaved min-of-N so both lanes
share the host's noise.  Gated under ``--smoke``:

- tracing-on phase wall time must stay <= 1.03x tracing-off (the
  ISSUE acceptance bar: observability must be cheap enough to leave
  on under chaos runs), and
- the produced trace must be schema-valid, contain the training-plane
  span vocabulary, and export to Perfetto ``trace_event`` JSON.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.data import shard_documents
from repro.infra.service import TrainingService
from repro.models.config import DiPaCoConfig
from repro.obs import Telemetry, read_trace, validate_trace
from repro.obs.perfetto import export_perfetto
from . import common

_W = 4


def _svc(s, ds, root, tel):
    dcfg = DiPaCoConfig(levels=(2, 2), inner_steps=2)
    return TrainingService(s["cfg"], dcfg, ds, key=s["key"],
                           ckpt_root=root, base_params=s["base"],
                           batch_size=4, peak_lr=1e-3, warmup=10,
                           total_steps=400, num_workers=2,
                           telemetry=tel)


# analysis: ignore[JAX105](run() returns host floats — every phase is synced before the clock reads)
def _measure(svc_off, svc_on, reps):
    """Interleaved min-of-N phase walls: (wall_off, wall_on)."""
    w_off, w_on = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        svc_off.run(1, tau=2)
        w_off.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        svc_on.run(1, tau=2)
        w_on.append(time.perf_counter() - t0)
    return min(w_off), min(w_on)


def run(quick: bool = True):
    s = common.setup(quick)
    docs, doms = s["docs"][:256], np.asarray(s["doms"][:256])
    ds = shard_documents(docs, doms % _W, _W)
    reps = 5 if quick else 9
    tpath = common.trace_path("obs")
    tel = Telemetry(tpath, meta={"suite": "obs"}, fresh=True)
    with tempfile.TemporaryDirectory() as root_off, \
            tempfile.TemporaryDirectory() as root_on:
        with _svc(s, ds, root_off, None) as svc_off, \
                _svc(s, ds, root_on, tel) as svc_on:
            svc_off.run(1, tau=2)      # warm the jit out of the timing
            svc_on.run(1, tau=2)
            wall_off, wall_on = _measure(svc_off, svc_on, reps)
    tel.close()

    ratio = wall_on / wall_off
    # the acceptance gate: full tracing must cost <= 3% phase wall
    assert ratio <= 1.03, (
        f"tracing overhead {100 * (ratio - 1):.2f}% > 3% "
        f"(on {wall_on:.4f}s vs off {wall_off:.4f}s per phase)")

    records, skipped = read_trace(tpath)
    errors = validate_trace(records)
    assert not errors, f"trace schema errors: {errors[:5]}"
    names = {r["name"] for r in records if r.get("k") in ("span", "ev")}
    required = {"train.phase", "train.fragment_send", "pool.task"}
    assert required <= names, (
        f"trace missing spans: {sorted(required - names)}")
    events, _ = export_perfetto(tpath, tpath.rsplit(".jsonl", 1)[0]
                                + ".perfetto.json")
    assert events > 0, "Perfetto export produced no events"

    rows = [{"name": "obs_overhead",
             "us_per_call": wall_on * 1e6,
             "wall_on_s": wall_on, "wall_off_s": wall_off,
             "overhead_ratio": ratio,
             "trace_records": len(records),
             "perfetto_events": events}]
    common.record_bench("obs_overhead", rows,
                        path=common.BENCH_TRAIN_PATH, trace=tpath)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
