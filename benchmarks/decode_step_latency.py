"""Per-tick decode latency / decode tokens-per-second microbenchmark.

Fills every slot of a multi-island continuous-batching engine, then
times steady-state decode ticks across the four decode configurations:

* attention impl: dense jnp cache branch vs the Pallas flash-decode
  kernel (interpret mode on this CPU container — kernel-dispatch
  structure is exercised; real-TPU timing is the deploy target);
* island dispatch: per-island Python loop (one jit call per path) vs
  the stacked-island tick (params stacked along a path axis, one
  vmapped dispatch advances every island).

Writes results into ``BENCH_decode.json`` so future PRs have a decode
perf trajectory to regress against.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import api
from repro.serving import ContinuousBatchingEngine, EngineOptions, Request

from .common import record_bench


def _fill_and_time(cfg, paths, *, stacked, slots, cache_len, prompt_len,
                   warm_ticks, ticks):
    eng = ContinuousBatchingEngine(cfg, paths, options=EngineOptions(
        cache_len=cache_len, slots_per_path=slots, stacked=stacked))
    num_paths = len(paths)
    counter = iter(range(10_000))
    eng._route_prompt = lambda prompt: next(counter) % num_paths
    rng = np.random.default_rng(0)
    total = num_paths * slots
    max_new = warm_ticks + ticks + 8   # keep every row in flight
    for rid in range(total):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, prompt_len).astype(
                np.int32),
            max_new=max_new))
    for _ in range(warm_ticks):        # admission tick + decode compile
        eng.step()
    assert len(eng.in_flight) == total
    jax.block_until_ready(eng.device_state())
    t0 = time.perf_counter()
    for _ in range(ticks):
        eng.step()
    jax.block_until_ready(eng.device_state())
    dt = time.perf_counter() - t0
    assert len(eng.in_flight) == total, "rows retired mid-measurement"
    return dt / ticks, total


def run(quick: bool = True):
    cfg = get_smoke_config("dipaco-150m").replace(route_prefix_len=8)
    # many small islands, few slots each (§2.2/§2.6 serving regime)
    num_paths, slots = (8, 4) if quick else (8, 8)
    ticks = 8 if quick else 20
    cache_len, prompt_len = 64, 16
    key = jax.random.PRNGKey(0)
    paths = [api.init_model(jax.random.fold_in(key, p), cfg)[0]
             for p in range(num_paths)]

    rows = []
    tick_s = {}
    for impl in ("chunked", "pallas"):
        for stacked in (False, True):
            per_tick, nrows = _fill_and_time(
                cfg.replace(attn_impl=impl), paths, stacked=stacked,
                slots=slots, cache_len=cache_len, prompt_len=prompt_len,
                warm_ticks=3, ticks=ticks)
            label = ("jnp" if impl == "chunked" else "pallas",
                     "stacked" if stacked else "looped")
            tick_s[label] = per_tick
            rows.append({
                "name": f"decode_{label[0]}_{label[1]}",
                "us_per_call": per_tick * 1e6,
                "tick_ms": per_tick * 1e3,
                "decode_tok_per_s": nrows / per_tick,
                "rows": nrows, "islands": num_paths,
            })
    rows.append({
        "name": "decode_stacked_speedup",
        "us_per_call": tick_s[("jnp", "stacked")] * 1e6,
        "jnp_loop_over_stacked":
            tick_s[("jnp", "looped")] / tick_s[("jnp", "stacked")],
        "pallas_loop_over_stacked":
            tick_s[("pallas", "looped")] / tick_s[("pallas", "stacked")],
    })
    record_bench("decode_step_latency", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
