"""Roofline report: reads the dry-run sweep JSON and emits the
EXPERIMENTS.md §Roofline table (terms in seconds, dominant bottleneck,
MODEL_FLOPS/HLO ratio, one-line bottleneck note)."""
from __future__ import annotations

import json
import os

_RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
_CANDIDATES = [os.path.join(_RESULTS, "dryrun_final.json"),
               os.path.join(_RESULTS, "dryrun_baseline.json")]
DEFAULT_PATH = next((p for p in _CANDIDATES if os.path.exists(p)),
                    _CANDIDATES[0])

_NOTES = {
    "collective_s": ("shrink TP activations crossing 'model' axis: "
                     "island-internal data parallelism / bf16 collectives"
                     " / fewer TP shards for small d_model"),
    "compute_s": ("cut non-useful FLOPs: causal chunk skipping, scatter "
                  "MoE dispatch, lighter remat policy"),
    "memory_s": ("decode is cache-bandwidth bound: shard cache seq over "
                 "'model', quantize KV, window the cache"),
}


def load(path: str = DEFAULT_PATH):
    with open(path) as f:
        return json.load(f)


def rows_from(records, mesh: str = "16x16"):
    rows = []
    for r in records:
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        rl = r["roofline"]
        rows.append({
            "name": f"roofline_{r['arch']}_{r['shape']}",
            "compute_s": round(rl["compute_s"], 6),
            "memory_s": round(rl["memory_s"], 6),
            "collective_s": round(rl["collective_s"], 6),
            "dominant": rl["dominant"],
            "model_flops": r.get("model_flops"),
            "useful_ratio": round(r.get("useful_flops_ratio", 0.0), 3),
            "note": _NOTES.get(rl["dominant"], ""),
            "us_per_call": rl["bound_s"] * 1e6,
        })
    return rows


def run(quick: bool = True, path: str = DEFAULT_PATH):
    if not os.path.exists(path):
        return [{"name": "roofline_missing",
                 "us_per_call": 0.0,
                 "note": f"run `python -m repro.launch.dryrun --all --out "
                         f"{path}` first"}]
    return rows_from(load(path))


def markdown_table(records, mesh="16x16") -> str:
    rows = rows_from(records, mesh)
    out = ["| arch | shape | compute_s | memory_s | collective_s | "
           "dominant | useful 6ND/HLO |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        arch, shape = r["name"][len("roofline_"):].rsplit("_", 1)
        out.append(
            f"| {arch} | {shape} | {r['compute_s']:.4g} | "
            f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
            f"{r['dominant'].replace('_s', '')} | {r['useful_ratio']} |")
    return "\n".join(out)


if __name__ == "__main__":
    for r in run():
        print(r)
