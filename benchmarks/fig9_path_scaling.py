"""Paper Fig. 9: validation PPL improves with more paths (and with
path-specific modules) at constant path size."""
from __future__ import annotations

import numpy as np

from repro.core.dipaco import DiPaCoTrainer
from repro.models.config import DiPaCoConfig
from . import common


def run(quick: bool = True):
    s = common.setup(quick)
    cfg, base, key = s["cfg"], s["base"], s["key"]
    phases, tau = (3, 10) if quick else (6, 25)
    rows = []
    grids = [(1, 2), (2, 2), (2, 4)] if quick else \
        [(1, 2), (2, 2), (2, 4), (4, 4)]
    for levels in grids:
        P = levels[0] * levels[1]
        ds, cents, _ = common.make_shards(s, P)
        ev = common.route_eval_docs(s, cents, P)
        tr = DiPaCoTrainer(cfg, DiPaCoConfig(levels=levels,
                                             inner_steps=tau), ds,
                           key=key, base_params=base, batch_size=8,
                           peak_lr=2e-3, warmup=10,
                           total_steps=phases * tau * 4)
        for _ in range(phases):
            tr.run_phase(tau)
        res = tr.evaluate_routed(s["val"], ev)
        rows.append({"name": f"dipaco_{levels[0]}x{levels[1]}_P{P}",
                     "val_ppl": res["ppl"], "us_per_call": 0.0})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
