"""Paper Fig. 8: convergence of DiPaCo (from a pretrained base) vs the
dense baseline and a larger dense model (miniature proxy: 2x width)."""
from __future__ import annotations

import numpy as np

from repro.core.dipaco import DiPaCoTrainer
from repro.data import shard_documents
from repro.models import api
from repro.models.config import DiPaCoConfig
from . import common


def run(quick: bool = True):
    import jax
    s = common.setup(quick)
    cfg, base, key = s["cfg"], s["base"], s["key"]
    phases, tau = (4, 10) if quick else (10, 25)
    rows = []

    ds, cents, _ = common.make_shards(s, 4)
    ev = common.route_eval_docs(s, cents, 4)
    tr = DiPaCoTrainer(cfg, DiPaCoConfig(levels=(2, 2), inner_steps=tau),
                       ds, key=key, base_params=base, batch_size=8,
                       peak_lr=2e-3, warmup=10,
                       total_steps=phases * tau * 4)
    curve = []
    for ph in range(phases):
        tr.run_phase(tau)
        curve.append(tr.evaluate_routed(s["val"], ev)["ppl"])
    rows.append({"name": "dipaco_2x2_curve",
                 "val_ppl": curve[-1],
                 "curve": [round(c, 3) for c in curve],
                 "us_per_call": 0.0})

    # dense baseline of path size, same steps, from the same base
    ds1 = shard_documents(s["docs"], np.zeros(len(s["docs"]), np.int32), 1)
    tr1 = DiPaCoTrainer(cfg, DiPaCoConfig(levels=(1,), inner_steps=tau),
                        ds1, key=key, base_params=base, batch_size=8,
                        peak_lr=2e-3, warmup=10,
                        total_steps=phases * tau * 4)
    curve1 = []
    for ph in range(phases):
        tr1.run_phase(tau)
        curve1.append(tr1.evaluate_routed(
            s["val"], np.zeros(len(s["val"]), np.int32))["ppl"])
    rows.append({"name": "dense_path_size_curve", "val_ppl": curve1[-1],
                 "curve": [round(c, 3) for c in curve1],
                 "us_per_call": 0.0})

    # larger dense model (2x d_model — the paper's 1.3B analogue)
    big = cfg.replace(d_model=cfg.d_model * 2, num_heads=cfg.num_heads * 2,
                      d_ff=cfg.d_ff * 2)
    kb = jax.random.PRNGKey(5)
    big_base, _ = api.init_model(kb, big)
    big_base = common.pretrain(big, big_base, s["docs"],
                               steps=60 if quick else 300)
    trb = DiPaCoTrainer(big, DiPaCoConfig(levels=(1,), inner_steps=tau),
                        ds1, key=kb, base_params=big_base, batch_size=8,
                        peak_lr=2e-3, warmup=10,
                        total_steps=phases * tau * 4)
    curveb = []
    for ph in range(phases):
        trb.run_phase(tau)
        curveb.append(trb.evaluate_routed(
            s["val"], np.zeros(len(s["val"]), np.int32))["ppl"])
    rows.append({"name": "dense_2x_curve", "val_ppl": curveb[-1],
                 "curve": [round(c, 3) for c in curveb],
                 "us_per_call": 0.0})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
