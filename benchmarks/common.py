"""Shared miniature-scale setup for the paper-table benchmarks.

The paper trains 150M-param paths for 88k steps on C4; this CPU
container runs the same *system* at miniature scale (2-layer d=128
paths, synthetic multi-domain corpus) so every table's comparison
structure is reproduced with honest wall-clock.  Scale factors are
recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.dipaco import DiPaCoTrainer
from repro.core.routing import (kmeans_fit, prefix_features,
                                train_discriminative_router)
from repro.core.routing.kmeans import kmeans_assign, topn_assign
from repro.data import SyntheticCorpus, shard_documents
from repro.models import api
from repro.models.config import DiPaCoConfig

VOCAB = 512
SEQ = 64
NUM_DOMAINS = 8
PREFIX = 8

BENCH_DECODE_PATH = "BENCH_decode.json"
BENCH_TRAIN_PATH = "BENCH_train.json"
BENCH_DEPLOY_PATH = "BENCH_deploy.json"

# where telemetry traces land; CI points this at its artifacts dir so
# the chaos/mesh shards upload Perfetto-loadable timelines
TRACE_DIR_ENV = "REPRO_TRACE_DIR"


def trace_path(suite: str) -> str:
    """Per-suite telemetry trace path under ``$REPRO_TRACE_DIR``
    (default ``artifacts/traces``)."""
    import os
    d = os.environ.get(TRACE_DIR_ENV) or os.path.join("artifacts",
                                                      "traces")
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{suite}.trace.jsonl")


def make_telemetry(suite: str, **kw):
    """A fresh :class:`repro.obs.Telemetry` tracing into the suite's
    trace file (one file per suite per run)."""
    from repro.obs import Telemetry
    return Telemetry(trace_path(suite), meta={"suite": suite},
                     fresh=True, **kw)


def record_bench(section: str, rows, path: str = BENCH_DECODE_PATH,
                 trace: str | None = None) -> None:
    """Merge a benchmark section into the perf-trajectory JSON so future
    PRs have numbers to regress against.  ``trace`` stamps every row
    with the telemetry trace file the numbers came from."""
    import json
    import os
    if trace is not None:
        rows = [{**r, "trace": trace} for r in rows]
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
    data[section] = {"recorded_at": time.strftime("%Y-%m-%d %H:%M:%S"),
                     "rows": rows}
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")


@functools.lru_cache(maxsize=1)
def setup(quick: bool = True):
    cfg = get_smoke_config("dipaco-150m").replace(route_prefix_len=PREFIX)
    corpus = SyntheticCorpus(vocab_size=VOCAB, num_domains=NUM_DOMAINS,
                             seq_len=SEQ, seed=0)
    n_train = 1024 if quick else 4096
    docs, doms = corpus.sample_documents(n_train, return_domains=True)
    val, val_doms = corpus.sample_documents(256, seed=99,
                                            return_domains=True)
    router_docs, router_doms = corpus.sample_documents(
        256, seed=7, return_domains=True)  # the paper's "router data"
    key = jax.random.PRNGKey(0)
    base, axes = api.init_model(key, cfg)
    # pretrain the base LM briefly (paper: 24k-step 150M pretrain, Fig. 8)
    base = pretrain(cfg, base, docs, steps=60 if quick else 300)
    return dict(cfg=cfg, corpus=corpus, docs=docs, doms=doms, val=val,
                val_doms=val_doms, router_docs=router_docs,
                router_doms=router_doms, base=base, key=key)


def pretrain(cfg, params, docs, *, steps: int, batch_size: int = 16,
             lr: float = 3e-3):
    from repro.optim import adamw_init, adamw_update

    opt = adamw_init(params)
    rng = np.random.default_rng(0)

    @jax.jit
    def step(p, o, batch, lr_):
        (loss, _), g = jax.value_and_grad(api.forward_loss, has_aux=True)(
            p, cfg, {"tokens": batch})
        p, o = adamw_update(g, o, p, lr=lr_)
        return p, o, loss

    for t in range(steps):
        idx = rng.integers(0, len(docs), size=batch_size)
        params, opt, loss = step(params, opt, jnp.asarray(docs[idx]),
                                 lr * min(1.0, (t + 1) / 20))
    return params


def make_shards(s, k, *, method="kmeans", overlap_topn=1, paths=None):
    """Route + pre-shard the training docs with the requested method."""
    cfg, base, docs = s["cfg"], s["base"], s["docs"]
    feats = prefix_features(base, cfg, jnp.asarray(docs), prefix_len=PREFIX)
    if method == "oracle":
        assign = s["doms"] % k
        cents = None
    elif method == "kmeans":
        cents, assign, _ = kmeans_fit(jax.random.PRNGKey(1), feats, k)
        if overlap_topn > 1:
            assign = np.asarray(topn_assign(feats, cents, overlap_topn))
    elif method == "product_kmeans":
        from repro.core.routing import (product_kmeans_assign,
                                        product_kmeans_fit)
        import math
        kk = int(math.isqrt(k))
        assert kk * kk == k
        cents, assign = product_kmeans_fit(jax.random.PRNGKey(1), feats, kk)
    else:
        raise ValueError(method)
    ds = shard_documents(docs, np.asarray(assign), k, holdout_frac=0.05)
    return ds, cents, feats


def route_eval_docs(s, cents, k):
    cfg, base = s["cfg"], s["base"]
    feats = prefix_features(base, cfg, jnp.asarray(s["val"]),
                            prefix_len=PREFIX)
    if cents is None:
        return s["val_doms"] % k
    a, _ = kmeans_assign(feats, cents)
    return np.asarray(a)


def train_trainer(trainer: DiPaCoTrainer, phases: int):
    t0 = time.time()
    hist = []
    for _ in range(phases):
        m = trainer.run_phase()
        hist.append(m.mean_loss)
    return hist, time.time() - t0


def ppl(nll: float) -> float:
    return float(np.exp(nll))
