"""Paper Table 2: flat MoE (fully independent paths) overfits as the
number of paths grows; overlapping shards (§2.4.4) partially rescue."""
from __future__ import annotations

import numpy as np

from repro.core.dipaco import DiPaCoTrainer, flat_moe_config
from repro.models.config import DiPaCoConfig
from . import common


def run(quick: bool = True):
    s = common.setup(quick)
    cfg, base, key = s["cfg"], s["base"], s["key"]
    phases, tau = (3, 10) if quick else (6, 25)
    rows = []
    for P, overlap in [(2, 1), (8, 1), (8, 2)]:
        ds, cents, _ = common.make_shards(s, P, overlap_topn=overlap)
        ev = common.route_eval_docs(s, cents, P)
        tr = DiPaCoTrainer(cfg, flat_moe_config(P, inner_steps=tau), ds,
                           key=key, base_params=base, batch_size=8,
                           peak_lr=2e-3, warmup=10,
                           total_steps=phases * tau * 4)
        train_hist = []
        for _ in range(phases):
            train_hist.append(tr.run_phase(tau).final_loss)
        res = tr.evaluate_routed(s["val"], ev)
        rows.append({"name": f"flat_moe_P{P}_top{overlap}",
                     "val_ppl": res["ppl"],
                     "train_nll": float(train_hist[-1]),
                     "gen_gap": res["nll"] - float(train_hist[-1]),
                     "us_per_call": 0.0})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
