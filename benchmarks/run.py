"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract).  ``derived``
packs the benchmark-specific result (PPL, ratios, notes) as
``k=v|k=v``.  ``--full`` runs the longer (non-quick) configurations.
"""
from __future__ import annotations

import argparse
import math
import sys
import time


def _derived(row: dict) -> str:
    skip = {"name", "us_per_call"}
    parts = []
    for k, v in row.items():
        if k in skip:
            continue
        if isinstance(v, float):
            v = f"{v:.6g}"
        parts.append(f"{k}={v}")
    return "|".join(parts)


# fast, CI-friendly subset exercising the kernel layer, the shared
# training harness (common.setup), the serving subsystem, the decode
# hot path, the async training service (async-vs-barrier), the
# deployment plane (publish/canary/hot-swap), the elastic-fleet
# chaos gate (30% mid-phase worker loss must stay within 2% of the
# stable fleet's loss — asserted inside the suite), the multi-process
# serving-fleet gate (token identity vs a single engine + adaptive
# speedup floor + one-promote hot swap — asserted inside the suite)
# and the telemetry overhead gate (tracing-on phase wall <= 1.03x
# tracing-off)
SMOKE_SUITES = ("kernels", "table2", "serving", "decode", "outer_exec",
                "deploy", "fleet", "fleet_serve", "obs")

# suites whose metrics must additionally be non-zero under --smoke (a
# zero decode latency / wall-clock / observed-lag / staleness means the
# measurement broke)
POSITIVE_SUITES = ("decode", "outer_exec", "deploy", "obs")


def _finite(row: dict) -> bool:
    return all(math.isfinite(v) for v in row.values()
               if isinstance(v, (int, float)))


# fields that are legitimately zero (e.g. observed staleness on a run
# where no shard happened to overtake a straggler) — not gated
ZERO_OK_FIELDS = {"max_observed_lag"}


def _positive(row: dict) -> bool:
    return all(v > 0 for k, v in row.items()
               if isinstance(v, (int, float)) and not isinstance(v, bool)
               and k not in ZERO_OK_FIELDS)


# per-suite headline field for the --smoke summary table: the first of
# these present in a suite's rows is reported next to its verdict
_KEY_FIELDS = ("overhead_ratio", "loss_delta_pct", "mean_loss", "ppl",
               "val_ppl", "p99_us", "p50_us", "tokens_per_s",
               "us_per_call")


class _Suite:
    """Adapter for a scenario function living inside another suite
    module (e.g. serving_throughput.run_fleet) so the harness can treat
    it like a module with a ``run``."""

    def __init__(self, fn):
        self.run = fn


def _key_metric(rows) -> str:
    for field in _KEY_FIELDS:
        for r in rows:
            v = r.get(field)
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                return f"{r['name']}.{field}={v:.6g}"
    return "-"


def _smoke_summary(results: dict, failures: list) -> None:
    """One table: suite, headline metric, gate verdict, plus the trace
    files the suites produced (what CI uploads for Perfetto)."""
    print("\nsuite        key metric                               gate")
    traces = set()
    for name, rows in results.items():
        if rows is None:
            print(f"{name:<12} {'(suite raised)':<40} FAIL")
            continue
        bad = any(f.startswith(f"{name}/") or f.startswith(f"{name}:")
                  for f in failures)
        print(f"{name:<12} {_key_metric(rows):<40} "
              f"{'FAIL' if bad else 'PASS'}")
        traces.update(r["trace"] for r in rows if r.get("trace"))
    for t in sorted(traces):
        print(f"trace: {t}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark module names")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: fast suite subset; exit non-zero on "
                         "any failure or non-finite metric")
    args = ap.parse_args()
    quick = not args.full

    from . import (decode_step_latency, deploy_latency, elastic_fleet,
                   fig8_convergence, fig9_path_scaling, fig11_alternating,
                   kernels_micro, obs_overhead, outer_exec_scaling,
                   roofline, serving_throughput, sync_vs_diloco,
                   table1_variants, table2_flatmoe_overfit,
                   table3_eval_routing, table5_sharding)
    suites = {
        "table1": table1_variants,
        "table2": table2_flatmoe_overfit,
        "table3": table3_eval_routing,
        "table5": table5_sharding,
        "fig8": fig8_convergence,
        "fig9": fig9_path_scaling,
        "fig11": fig11_alternating,
        "sync_vs_diloco": sync_vs_diloco,
        "outer_exec": outer_exec_scaling,
        "fleet": elastic_fleet,
        "kernels": kernels_micro,
        "roofline": roofline,
        "serving": serving_throughput,
        "fleet_serve": _Suite(serving_throughput.run_fleet),
        "decode": decode_step_latency,
        "deploy": deploy_latency,
        "obs": obs_overhead,
    }
    if args.smoke:
        suites = {k: suites[k] for k in SMOKE_SUITES}
    if args.only:
        names = args.only.split(",")
        unknown = [n for n in names if n not in suites]
        if unknown:
            ap.error(f"unknown suite(s) {unknown}; "
                     f"known: {sorted(suites)}")
        suites = {k: v for k, v in suites.items() if k in names}

    failures = []
    results = {}
    print("name,us_per_call,derived")
    for name, mod in suites.items():
        t0 = time.time()
        try:
            rows = mod.run(quick=quick)
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,error={type(e).__name__}: {e}")
            failures.append(f"{name}: {type(e).__name__}: {e}")
            results[name] = None
            continue
        results[name] = rows
        for r in rows:
            if args.smoke and not _finite(r):
                failures.append(f"{name}/{r['name']}: non-finite metric")
            if (args.smoke and name in POSITIVE_SUITES
                    and not _positive(r)):
                failures.append(f"{name}/{r['name']}: zero metric")
            print(f"{r['name']},{r.get('us_per_call', 0.0):.1f},"
                  f"{_derived(r)}")
        print(f"# {name} finished in {time.time() - t0:.1f}s",
              file=sys.stderr)
    if args.smoke:
        _smoke_summary(results, failures)
    if args.smoke and failures:
        for f in failures:
            print(f"SMOKE FAILURE: {f}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
