"""Paper §4.5: DiLoCo (communicate every tau steps) vs fully-synchronous
per-step gradient mixing — the paper finds DiLoCo matches or slightly
beats sync despite ~tau x less communication."""
from __future__ import annotations

import numpy as np

from repro.core.dipaco import DiPaCoTrainer, SyncDiPaCoTrainer
from repro.models.config import DiPaCoConfig
from . import common


def run(quick: bool = True):
    s = common.setup(quick)
    cfg, base, key = s["cfg"], s["base"], s["key"]
    phases, tau = (4, 10) if quick else (8, 25)
    ds, cents, _ = common.make_shards(s, 4)
    ev = common.route_eval_docs(s, cents, 4)
    rows = []
    for name, cls in [("diloco", DiPaCoTrainer),
                      ("fully_sync", SyncDiPaCoTrainer)]:
        tr = cls(cfg, DiPaCoConfig(levels=(2, 2), inner_steps=tau), ds,
                 key=key, base_params=base, batch_size=8, peak_lr=2e-3,
                 warmup=10, total_steps=phases * tau * 4)
        for _ in range(phases):
            tr.run_phase(tau)
        res = tr.evaluate_routed(s["val"], ev)
        comms = phases if name == "diloco" else phases * tau
        rows.append({"name": f"sync_ablation_{name}",
                     "val_ppl": res["ppl"], "comm_rounds": comms,
                     "us_per_call": 0.0})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
