"""Paper Table 5 (+§7.2.1): sharding method impact — k-means vs product
k-means vs discriminative (one alternating EM phase)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.dipaco import DiPaCoTrainer
from repro.core.routing import (prefix_features,
                                train_discriminative_router)
from repro.core.routing.discriminative import score_documents
from repro.data import shard_documents
from repro.models.config import DiPaCoConfig
from . import common


def run(quick: bool = True):
    s = common.setup(quick)
    cfg, base, key = s["cfg"], s["base"], s["key"]
    phases, tau = (3, 10) if quick else (6, 25)
    P = 4
    rows = []

    def train_on(ds, ev, name):
        tr = DiPaCoTrainer(cfg, DiPaCoConfig(levels=(2, 2),
                                             inner_steps=tau), ds,
                           key=key, base_params=base, batch_size=8,
                           peak_lr=2e-3, warmup=10,
                           total_steps=phases * tau * 4)
        for _ in range(phases):
            tr.run_phase(tau)
        res = tr.evaluate_routed(s["val"], ev)
        rows.append({"name": name, "val_ppl": res["ppl"],
                     "us_per_call": 0.0})
        return tr

    ds, cents, feats = common.make_shards(s, P, method="kmeans")
    ev = common.route_eval_docs(s, cents, P)
    tr_km = train_on(ds, ev, "kmeans")

    ds_pk, cents_pk, _ = common.make_shards(s, P, method="product_kmeans")
    from repro.core.routing import product_kmeans_assign
    vfeats = prefix_features(base, cfg, jax.numpy.asarray(s["val"]),
                             prefix_len=common.PREFIX)
    ev_pk = np.asarray(product_kmeans_assign(vfeats, cents_pk))
    train_on(ds_pk, ev_pk, "product_kmeans")

    # discriminative: one EM phase — score router-data with the k-means-
    # trained paths, fit the logistic router, re-shard, re-train
    paths = [tr_km.path_params(p) for p in range(P)]
    rdocs = jax.numpy.asarray(s["router_docs"])
    scores = score_documents(paths, cfg, rdocs)
    targets = np.asarray(scores.argmax(axis=1))
    rfeats = prefix_features(base, cfg, rdocs, prefix_len=common.PREFIX)
    router = train_discriminative_router(jax.random.PRNGKey(2), rfeats,
                                         targets, P, steps=300)
    tfeats = prefix_features(base, cfg, jax.numpy.asarray(s["docs"]),
                             prefix_len=common.PREFIX)
    new_assign = np.asarray(router.assign(tfeats))
    ds_d = shard_documents(s["docs"], new_assign, P, holdout_frac=0.05)
    ev_d = np.asarray(router.assign(vfeats))
    train_on(ds_d, ev_d, "discriminative")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
