"""Paper Table 1: Baseline vs DiLoCo vs Flat MoE vs DiPaCo (+path-
specific modules) at equal weight-update steps (miniature scale)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.dipaco import (DiPaCoTrainer, diloco_config,
                               flat_moe_config)
from repro.data import shard_documents
from repro.models.config import DiPaCoConfig
from . import common


def run(quick: bool = True):
    s = common.setup(quick)
    cfg, base, key = s["cfg"], s["base"], s["key"]
    phases, tau = (3, 10) if quick else (8, 25)
    rows = []

    def bench(name, dcfg, ds, eval_assign, total_params_factor):
        t0 = time.time()
        tr = DiPaCoTrainer(cfg, dcfg, ds, key=key, base_params=base,
                           batch_size=8, peak_lr=2e-3, warmup=10,
                           total_steps=phases * tau * 4)
        for _ in range(phases):
            tr.run_phase(tau)
        res = tr.evaluate_routed(s["val"], eval_assign)
        dt = time.time() - t0
        rows.append({
            "name": name, "val_ppl": res["ppl"], "val_nll": res["nll"],
            "params_factor": total_params_factor,
            "us_per_call": dt / (phases * tau) * 1e6, "wall_s": dt})
        return res

    # Baseline: single path, same steps, all data
    ds1 = shard_documents(s["docs"], np.zeros(len(s["docs"]), np.int32), 1)
    bench("baseline_1path", DiPaCoConfig(levels=(1,), inner_steps=tau),
          ds1, np.zeros(len(s["val"]), np.int32), 1.0)

    # DiLoCo P=4: one module, 4 workers, 4x data
    ds4u = shard_documents(s["docs"], np.arange(len(s["docs"])) % 4, 4)
    bench("diloco_P4", diloco_config(4, inner_steps=tau), ds4u,
          np.zeros(len(s["val"]), np.int32), 1.0)

    # routed variants share a k-means sharding
    ds4, cents, _ = common.make_shards(s, 4, method="kmeans")
    ev4 = common.route_eval_docs(s, cents, 4)
    bench("flat_moe_P4", flat_moe_config(4, inner_steps=tau), ds4, ev4, 4.0)
    bench("dipaco_2x2", DiPaCoConfig(levels=(2, 2), inner_steps=tau),
          ds4, ev4, 2.0)
    bench("dipaco_2x2_pathspec",
          DiPaCoConfig(levels=(2, 2), inner_steps=tau,
                       path_specific_levels=(1,)),
          ds4, ev4, 2.0 + 1.0)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
