"""Paper Fig. 11 (appendix §7.2.1): more alternating discriminative
re-sharding (EM) phases improve PPL with diminishing returns.

As in the paper's Fig. 10 "branching" protocol, training CONTINUES from
the previous round's paths after each re-shard (coordinate descent:
update paths, then update assignments)."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.dipaco import DiPaCoTrainer, flat_moe_config
from repro.core.routing import (prefix_features,
                                train_discriminative_router)
from repro.core.routing.discriminative import score_documents
from repro.data import shard_documents
from repro.data.loader import ShardLoader
from . import common


def run(quick: bool = True):
    s = common.setup(quick)
    cfg, base, key = s["cfg"], s["base"], s["key"]
    phases_per_em, tau = (2, 10) if quick else (3, 25)
    P = 4
    em_rounds = 3 if quick else 4
    rows = []
    ds, cents, feats = common.make_shards(s, P, method="kmeans")
    ev = common.route_eval_docs(s, cents, P)
    tr = DiPaCoTrainer(cfg, flat_moe_config(P, inner_steps=tau), ds,
                       key=key, base_params=base, batch_size=8,
                       peak_lr=2e-3, warmup=10,
                       total_steps=em_rounds * phases_per_em * tau)
    router = None
    for em in range(em_rounds):
        for _ in range(phases_per_em):
            tr.run_phase(tau)
        if router is not None:
            vfeats = prefix_features(base, cfg,
                                     jax.numpy.asarray(s["val"]),
                                     prefix_len=common.PREFIX)
            ev = np.asarray(router.assign(vfeats))
        res = tr.evaluate_routed(s["val"], ev)
        rows.append({"name": f"alternating_em_phase{em}",
                     "val_ppl": res["ppl"], "us_per_call": 0.0})
        if em == em_rounds - 1:
            break
        # E-step: discriminative re-shard; M-step continues in-place
        paths = [tr.path_params(p) for p in range(P)]
        rdocs = jax.numpy.asarray(s["router_docs"])
        scores = score_documents(paths, cfg, rdocs)
        rfeats = prefix_features(base, cfg, rdocs,
                                 prefix_len=common.PREFIX)
        router = train_discriminative_router(
            jax.random.PRNGKey(10 + em), rfeats,
            np.asarray(scores.argmax(axis=1)), P, steps=200)
        tfeats = prefix_features(base, cfg, jax.numpy.asarray(s["docs"]),
                                 prefix_len=common.PREFIX)
        new_ds = shard_documents(s["docs"],
                                 np.asarray(router.assign(tfeats)), P,
                                 holdout_frac=0.05)
        tr.dataset = new_ds
        tr.loaders = [ShardLoader(sh, 8, seed=500 + em * 17 + i)
                      for i, sh in enumerate(new_ds.shards)]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
