"""Paper §3.3: sharded outer-optimization executors with online
accumulation vs a naive monolithic averager — wall-clock per outer step
and peak working-set proxy."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.module_store import ModuleStore
from repro.core.partition import make_partition
from repro.infra.outer_executor import ShardedOuterExecutors
from repro.models import api
from repro.models.config import DiPaCoConfig
from . import common


def run(quick: bool = True):
    s = common.setup(quick)
    cfg, base, key = s["cfg"], s["base"], s["key"]
    P = 8
    dcfg = DiPaCoConfig(levels=(2, 4))
    part = make_partition(dcfg, cfg.pattern_repeats)
    _, axes = api.init_model(key, cfg)
    deltas = [jax.tree_util.tree_map(
        lambda x: jnp.full(x.shape, 0.01 * (w + 1), jnp.float32), base)
        for w in range(P)]
    rows = []

    # sharded online: accumulate as checkpoints "arrive"
    store = ModuleStore(base, axes, part)
    execs = ShardedOuterExecutors(store, part, np.arange(P))
    t0 = time.time()
    for w in range(P):
        execs.accumulate(w, deltas[w])
    dt_sharded = time.time() - t0

    # naive: wait for all, average full trees in one place
    t0 = time.time()
    acc = jax.tree_util.tree_map(jnp.zeros_like, deltas[0])
    for w in range(P):
        acc = jax.tree_util.tree_map(lambda a, d: a + d / P, acc,
                                     deltas[w])
    jax.block_until_ready(jax.tree_util.tree_leaves(acc)[0])
    dt_naive = time.time() - t0

    module_bytes = max(
        sum(x.size * 4 for x in jax.tree_util.tree_leaves(
            store.module_params(l, 0)) if x is not None)
        for l in range(part.num_levels))
    full_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(base))
    rows.append({"name": "outer_exec_sharded_online",
                 "us_per_call": dt_sharded / P * 1e6,
                 "peak_module_bytes": module_bytes,
                 "outer_updates": execs.total_updates})
    rows.append({"name": "outer_exec_naive_monolithic",
                 "us_per_call": dt_naive / P * 1e6,
                 "peak_module_bytes": full_bytes,
                 "outer_updates": 1})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
