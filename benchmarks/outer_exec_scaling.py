"""Paper §3.3: sharded outer-optimization executors with online
accumulation vs a naive monolithic averager — wall-clock per outer step
and peak working-set proxy — plus the §3 async-vs-barrier comparison:
the same miniature training run through the global-barrier round
trainer and through the phase-pipelined ``TrainingService``
(``max_phase_lag=1``) with one deliberately slow shard.  The barrier
pays the straggler every phase; the pipelined service overlaps it.

Streaming fragment-wise outer sync (Streaming DiLoCo): the same run
with the classic one-burst fp32 outer sync vs 4 staggered fragments +
int8 outer gradients — simulated peak bytes per sync instant must drop
>= 4x with < 1% phase-loss regression (both gated under ``--smoke``).

Mesh lane (real collectives): burst (K=1) vs overlapped streaming
(K=4, int8) through ``launch.steps.make_streaming_mesh_phase`` in a
subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
— one worker row per XLA device, every fragment reduce an actual
cross-device all_gather.  Streaming dispatches fragment f's reduce
before segment f+1's inner compute, so its wall-clock per phase must
not exceed burst's (gated under ``--smoke``).  Results are recorded to
``BENCH_train.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.module_store import ModuleStore
from repro.core.partition import make_partition
from repro.infra.outer_executor import ShardedOuterExecutors
from repro.models import api
from repro.models.config import DiPaCoConfig
from . import common


def _executor_rows(s):
    cfg, base, key = s["cfg"], s["base"], s["key"]
    P = 8
    dcfg = DiPaCoConfig(levels=(2, 4))
    part = make_partition(dcfg, cfg.pattern_repeats)
    _, axes = api.init_model(key, cfg)
    deltas = [jax.tree_util.tree_map(
        lambda x: jnp.full(x.shape, 0.01 * (w + 1), jnp.float32), base)
        for w in range(P)]
    rows = []

    # sharded online: accumulate as checkpoints "arrive"
    store = ModuleStore(base, axes, part)
    execs = ShardedOuterExecutors(store, part, np.arange(P))
    t0 = time.time()
    for w in range(P):
        execs.accumulate(w, deltas[w])
    dt_sharded = time.time() - t0

    # naive: wait for all, average full trees in one place
    t0 = time.time()
    acc = jax.tree_util.tree_map(jnp.zeros_like, deltas[0])
    for w in range(P):
        acc = jax.tree_util.tree_map(lambda a, d: a + d / P, acc,
                                     deltas[w])
    jax.block_until_ready(jax.tree_util.tree_leaves(acc)[0])
    dt_naive = time.time() - t0

    module_bytes = max(
        sum(x.size * 4 for x in jax.tree_util.tree_leaves(
            store.module_params(l, 0)) if x is not None)
        for l in range(part.num_levels))
    full_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(base))
    rows.append({"name": "outer_exec_sharded_online",
                 "us_per_call": dt_sharded / P * 1e6,
                 "peak_module_bytes": module_bytes,
                 "outer_updates": execs.total_updates})
    rows.append({"name": "outer_exec_naive_monolithic",
                 "us_per_call": dt_naive / P * 1e6,
                 "peak_module_bytes": full_bytes,
                 "outer_updates": 1})
    return rows


def _async_vs_barrier_rows(s, quick: bool):
    """Same run through both regimes under *stochastic* stalls — the
    paper's preemption/jitter scenario.  Each (shard, phase) task stalls
    with probability ``stall_prob`` on a schedule deterministic in
    (shard, phase), so both modes see the identical stall set.  The
    barrier pays (almost) every phase's worst stall; the pipelined
    service overlaps a stalled shard with the other shards' next
    phase."""
    from repro.data import shard_documents
    from repro.infra.service import TrainingService

    cfg, key = s["cfg"], s["key"]
    W = 4
    docs, doms = s["docs"][:256], np.asarray(s["doms"][:256])
    ds = shard_documents(docs, doms % W, W)
    dcfg = DiPaCoConfig(levels=(2, 2), inner_steps=2)
    phases, stall, stall_prob = (4, 0.4, 0.5) if quick else (8, 0.5, 0.5)

    def stall_s(shard: int, phase: int) -> float:
        rng = np.random.default_rng(97 + shard * 131 + phase * 7919)
        return stall if rng.random() < stall_prob else 0.0

    results = {}
    for mode, lag in (("barrier", 0), ("async_lag1", 1)):
        with tempfile.TemporaryDirectory() as root:
            svc = TrainingService(
                cfg, dcfg, ds, key=key, ckpt_root=root,
                base_params=s["base"], batch_size=4, peak_lr=1e-3,
                warmup=10, total_steps=200, num_workers=W,
                max_phase_lag=lag)
            inner = svc._handle

            def jittered(task, _inner=inner):
                time.sleep(stall_s(task.payload["shard_id"],
                                   task.payload["phase"]))
                return _inner(task)

            svc.pool.handler = jittered
            svc.run(1)                    # warm the jit out of the timing
            t0 = time.time()
            m = svc.run(phases)
            dt = time.time() - t0
            results[mode] = (dt, m)
            svc.shutdown()
    dt_b, _ = results["barrier"]
    dt_a, m_a = results["async_lag1"]
    return [
        {"name": "train_service_barrier",
         "us_per_call": dt_b / phases * 1e6,
         "wall_s_per_phase": dt_b / phases, "phases": phases,
         "stall_s": stall, "stall_prob": stall_prob},
        {"name": "train_service_async_lag1",
         "us_per_call": dt_a / phases * 1e6,
         "wall_s_per_phase": dt_a / phases, "phases": phases,
         "stall_s": stall, "stall_prob": stall_prob,
         "max_observed_lag": m_a["max_observed_lag"],
         "outer_updates": m_a["outer_updates"],
         "speedup_vs_barrier": dt_b / dt_a},
    ]


def _streaming_rows(s, quick: bool):
    """Classic one-burst fp32 outer sync vs streaming fragment-wise
    sync with quantized outer gradients, same run otherwise.  Single
    pool worker keeps the accumulation order (and hence the loss)
    deterministic; the comparison is bandwidth shape + quality, the
    wall-clock overlap is covered by the async-vs-barrier rows."""
    from repro.data import shard_documents
    from repro.infra.service import TrainingService

    cfg, key = s["cfg"], s["key"]
    W = 4
    docs, doms = s["docs"][:256], np.asarray(s["doms"][:256])
    ds = shard_documents(docs, doms % W, W)
    phases = 3 if quick else 6
    variants = {
        "burst_fp32": {},
        "stream_frag4_int8": dict(outer_fragments=4, fragment_stagger=1,
                                  comm_dtype="int8"),
    }
    runs = {}
    tel = common.make_telemetry("outer_exec")
    for name, over in variants.items():
        dcfg = DiPaCoConfig(levels=(2, 2), inner_steps=2, **over)
        tel.instant("bench.section", section=f"outer_sync_{name}")
        with tempfile.TemporaryDirectory() as root:
            svc = TrainingService(
                cfg, dcfg, ds, key=key, ckpt_root=root,
                base_params=s["base"], batch_size=4, peak_lr=1e-3,
                warmup=10, total_steps=200, num_workers=1,
                telemetry=tel)
            svc.run(1, tau=2)             # warm the jit out of the timing
            # the warmup phase must not pollute the recorded comms
            # (peak is schedule-determined, but sends/totals are counts)
            svc.reset_comm_stats()
            t0 = time.time()
            m = svc.run(phases, tau=2)
            dt = time.time() - t0
            runs[name] = (m, m["comm"], dt)
            svc.shutdown()
    tel.close()
    mb, cb, dtb = runs["burst_fp32"]
    ms, cs, dts = runs["stream_frag4_int8"]
    peak_reduction = cb["peak_sync_bytes"] / max(cs["peak_sync_bytes"], 1)
    loss_ratio = ms["mean_loss"] / mb["mean_loss"]
    # the headline claims, gated in --smoke (run.py turns an exception
    # into a non-zero exit): streaming must cut the sync-instant
    # bandwidth burst >= 4x without hurting the phase loss > 1%
    assert peak_reduction >= 4.0, (
        f"peak comms reduction {peak_reduction:.2f}x < 4x "
        f"({cb['peak_sync_bytes']} -> {cs['peak_sync_bytes']} bytes)")
    assert loss_ratio <= 1.01, (
        f"streaming phase-loss regression {100 * (loss_ratio - 1):.2f}% "
        f"> 1% ({mb['mean_loss']:.4f} -> {ms['mean_loss']:.4f})")
    return [
        {"name": "outer_sync_burst_fp32",
         "us_per_call": dtb / phases * 1e6,
         "wall_s_per_phase": dtb / phases, "phases": phases,
         "peak_sync_bytes": cb["peak_sync_bytes"],
         "total_comm_bytes": cb["total_comm_bytes"],
         "sends": cb["sends"], "mean_loss": mb["mean_loss"]},
        {"name": "outer_sync_stream_frag4_int8",
         "us_per_call": dts / phases * 1e6,
         "wall_s_per_phase": dts / phases, "phases": phases,
         "peak_sync_bytes": cs["peak_sync_bytes"],
         "total_comm_bytes": cs["total_comm_bytes"],
         "sends": cs["sends"], "mean_loss": ms["mean_loss"],
         "peak_comms_reduction": peak_reduction,
         "total_comms_reduction":
             cb["total_comm_bytes"] / max(cs["total_comm_bytes"], 1),
         "loss_ratio_vs_burst": loss_ratio},
    ]


_MESH_MARK = "MESH_LANE_ROWS:"


def _mesh_lane_child(quick: bool):
    """Child entry point (8 forced host devices): burst K=1 vs
    overlapped streaming K=4 int8 through the identical
    ``make_streaming_mesh_phase`` code path, min-of-N phase wall."""
    from repro.configs import get_smoke_config
    from repro.core.diloco import fragment_state_init
    from repro.core.dipaco import stack_tree
    from repro.core.fragments import FragmentSpec, segment_bounds
    from repro.core.partition import make_partition, mixing_matrices
    from repro.launch.mesh import make_worker_mesh
    from repro.launch.steps import make_streaming_mesh_phase
    from repro.models.config import DiPaCoConfig
    from repro.optim import adamw_init

    ndev = len(jax.devices())
    assert ndev == 8, f"mesh lane expected 8 forced devices, got {ndev}"
    cfg = get_smoke_config("dipaco-150m").replace(
        route_prefix_len=common.PREFIX)
    W, B, T = 8, 2, common.SEQ
    tau, reps = (8, 5) if quick else (16, 7)
    key = jax.random.PRNGKey(0)
    base, axes = api.init_model(key, cfg)
    worker0 = stack_tree(base, W)
    glob0 = stack_tree(jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), base), W)
    opt0 = jax.vmap(adamw_init)(worker0)
    part = make_partition(DiPaCoConfig(levels=(2, 4)),
                          cfg.pattern_repeats)
    mixl, mixs = mixing_matrices(part, np.arange(W) % part.num_paths)
    mixl, mixs = jnp.asarray(mixl), jnp.asarray(mixs)
    mesh = make_worker_mesh(W)
    rng = np.random.default_rng(0)
    batches = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (tau, W, B, T)).astype(np.int32))
    lrs = jnp.linspace(1e-3, 5e-4, tau).astype(jnp.float32)

    def build(K, comm):
        spec = FragmentSpec(glob0, K)
        states = fragment_state_init(glob0, spec)
        bounds = segment_bounds(tau, K)
        seg_b = [batches[bounds[s]:bounds[s + 1]] for s in range(K)]
        seg_l = [lrs[bounds[s]:bounds[s + 1]] for s in range(K)]
        phase = make_streaming_mesh_phase(cfg, mesh, axes, spec,
                                          comm_dtype=comm)

        def once():
            out = phase(worker0, opt0, glob0, states, {}, mixl, mixs,
                        seg_b, seg_l)
            jax.block_until_ready(out)
            return out

        return once

    lanes = [("mesh_burst_k1_fp32", 1, "fp32"),
             ("mesh_stream_frag4_int8", 4, "int8")]
    fns = [build(K, comm) for _, K, comm in lanes]
    outs = [fn() for fn in fns]             # compile out of the timing
    walls = [[] for _ in lanes]
    for _ in range(reps):                   # interleave: shared noise
        for i, fn in enumerate(fns):
            t0 = time.time()
            fn()
            walls[i].append(time.time() - t0)
    rows = []
    for (name, K, comm), w, out in zip(lanes, walls, outs):
        wall = min(w)                       # min-of-N: noise-floor cost
        rows.append({"name": name, "us_per_call": wall * 1e6,
                     "wall_s_per_phase": wall, "devices": ndev,
                     "workers": W, "fragments": K, "comm_dtype": comm,
                     "tau": tau,
                     "mean_loss": float(np.asarray(out[-1]).mean())})
    burst, stream = rows
    ratio = stream["wall_s_per_phase"] / burst["wall_s_per_phase"]
    stream["wall_ratio_vs_burst"] = ratio
    stream["speedup_vs_burst"] = 1.0 / ratio
    # the overlap claim, gated in --smoke: splitting the phase into K
    # segments and dispatching fragment f's reduce before segment f+1's
    # compute must not cost wall-clock vs the one-burst baseline.  On a
    # single-core host the reduce cannot run concurrently with compute
    # (no idle parallelism), so "no penalty" is asserted within the
    # measured dispatch-noise floor; on real multi-device hardware the
    # overlap is the win.
    assert ratio <= 1.05, (
        f"streaming phase wall {stream['wall_s_per_phase']:.3f}s "
        f"exceeds burst {burst['wall_s_per_phase']:.3f}s by "
        f"{100 * (ratio - 1):.1f}% (> 5% noise floor)")
    print(_MESH_MARK + json.dumps(rows))


def _mesh_lane_rows(quick: bool):
    """Run the mesh lane in a subprocess where XLA can still be told to
    present 8 host devices (the parent's device count is locked at its
    first jax use)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    cmd = [sys.executable, "-m", "benchmarks.outer_exec_scaling",
           "--mesh-lane"] + ([] if quick else ["--full"])
    out = subprocess.run(cmd, env=env, cwd=root, capture_output=True,
                         text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"mesh lane failed:\n{out.stdout[-2000:]}\n"
                           f"{out.stderr[-2000:]}")
    for line in out.stdout.splitlines():
        if line.startswith(_MESH_MARK):
            return json.loads(line[len(_MESH_MARK):])
    raise RuntimeError(f"mesh lane produced no rows:\n{out.stdout[-2000:]}")


def run(quick: bool = True):
    s = common.setup(quick)
    rows = _executor_rows(s)
    rows += _async_vs_barrier_rows(s, quick)
    rows += _streaming_rows(s, quick)
    rows += _mesh_lane_rows(quick)
    common.record_bench("outer_exec_async", rows,
                        path=common.BENCH_TRAIN_PATH,
                        trace=common.trace_path("outer_exec"))
    return rows


if __name__ == "__main__":
    if "--mesh-lane" in sys.argv:
        _mesh_lane_child(quick="--full" not in sys.argv)
    else:
        for r in run():
            print(r)
