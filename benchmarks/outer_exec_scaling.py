"""Paper §3.3: sharded outer-optimization executors with online
accumulation vs a naive monolithic averager — wall-clock per outer step
and peak working-set proxy — plus the §3 async-vs-barrier comparison:
the same miniature training run through the global-barrier round
trainer and through the phase-pipelined ``TrainingService``
(``max_phase_lag=1``) with one deliberately slow shard.  The barrier
pays the straggler every phase; the pipelined service overlaps it.

Streaming fragment-wise outer sync (Streaming DiLoCo): the same run
with the classic one-burst fp32 outer sync vs 4 staggered fragments +
int8 outer gradients — simulated peak bytes per sync instant must drop
>= 4x with < 1% phase-loss regression (both gated under ``--smoke``).
Results are recorded to ``BENCH_train.json``.
"""
from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.module_store import ModuleStore
from repro.core.partition import make_partition
from repro.infra.outer_executor import ShardedOuterExecutors
from repro.models import api
from repro.models.config import DiPaCoConfig
from . import common


def _executor_rows(s):
    cfg, base, key = s["cfg"], s["base"], s["key"]
    P = 8
    dcfg = DiPaCoConfig(levels=(2, 4))
    part = make_partition(dcfg, cfg.pattern_repeats)
    _, axes = api.init_model(key, cfg)
    deltas = [jax.tree_util.tree_map(
        lambda x: jnp.full(x.shape, 0.01 * (w + 1), jnp.float32), base)
        for w in range(P)]
    rows = []

    # sharded online: accumulate as checkpoints "arrive"
    store = ModuleStore(base, axes, part)
    execs = ShardedOuterExecutors(store, part, np.arange(P))
    t0 = time.time()
    for w in range(P):
        execs.accumulate(w, deltas[w])
    dt_sharded = time.time() - t0

    # naive: wait for all, average full trees in one place
    t0 = time.time()
    acc = jax.tree_util.tree_map(jnp.zeros_like, deltas[0])
    for w in range(P):
        acc = jax.tree_util.tree_map(lambda a, d: a + d / P, acc,
                                     deltas[w])
    jax.block_until_ready(jax.tree_util.tree_leaves(acc)[0])
    dt_naive = time.time() - t0

    module_bytes = max(
        sum(x.size * 4 for x in jax.tree_util.tree_leaves(
            store.module_params(l, 0)) if x is not None)
        for l in range(part.num_levels))
    full_bytes = sum(x.size * 4 for x in jax.tree_util.tree_leaves(base))
    rows.append({"name": "outer_exec_sharded_online",
                 "us_per_call": dt_sharded / P * 1e6,
                 "peak_module_bytes": module_bytes,
                 "outer_updates": execs.total_updates})
    rows.append({"name": "outer_exec_naive_monolithic",
                 "us_per_call": dt_naive / P * 1e6,
                 "peak_module_bytes": full_bytes,
                 "outer_updates": 1})
    return rows


def _async_vs_barrier_rows(s, quick: bool):
    """Same run through both regimes under *stochastic* stalls — the
    paper's preemption/jitter scenario.  Each (shard, phase) task stalls
    with probability ``stall_prob`` on a schedule deterministic in
    (shard, phase), so both modes see the identical stall set.  The
    barrier pays (almost) every phase's worst stall; the pipelined
    service overlaps a stalled shard with the other shards' next
    phase."""
    from repro.data import shard_documents
    from repro.infra.service import TrainingService

    cfg, key = s["cfg"], s["key"]
    W = 4
    docs, doms = s["docs"][:256], np.asarray(s["doms"][:256])
    ds = shard_documents(docs, doms % W, W)
    dcfg = DiPaCoConfig(levels=(2, 2), inner_steps=2)
    phases, stall, stall_prob = (4, 0.4, 0.5) if quick else (8, 0.5, 0.5)

    def stall_s(shard: int, phase: int) -> float:
        rng = np.random.default_rng(97 + shard * 131 + phase * 7919)
        return stall if rng.random() < stall_prob else 0.0

    results = {}
    for mode, lag in (("barrier", 0), ("async_lag1", 1)):
        with tempfile.TemporaryDirectory() as root:
            svc = TrainingService(
                cfg, dcfg, ds, key=key, ckpt_root=root,
                base_params=s["base"], batch_size=4, peak_lr=1e-3,
                warmup=10, total_steps=200, num_workers=W,
                max_phase_lag=lag)
            inner = svc._handle

            def jittered(task, _inner=inner):
                time.sleep(stall_s(task.payload["shard_id"],
                                   task.payload["phase"]))
                return _inner(task)

            svc.pool.handler = jittered
            svc.run(1)                    # warm the jit out of the timing
            t0 = time.time()
            m = svc.run(phases)
            dt = time.time() - t0
            results[mode] = (dt, m)
            svc.shutdown()
    dt_b, _ = results["barrier"]
    dt_a, m_a = results["async_lag1"]
    return [
        {"name": "train_service_barrier",
         "us_per_call": dt_b / phases * 1e6,
         "wall_s_per_phase": dt_b / phases, "phases": phases,
         "stall_s": stall, "stall_prob": stall_prob},
        {"name": "train_service_async_lag1",
         "us_per_call": dt_a / phases * 1e6,
         "wall_s_per_phase": dt_a / phases, "phases": phases,
         "stall_s": stall, "stall_prob": stall_prob,
         "max_observed_lag": m_a["max_observed_lag"],
         "outer_updates": m_a["outer_updates"],
         "speedup_vs_barrier": dt_b / dt_a},
    ]


def _streaming_rows(s, quick: bool):
    """Classic one-burst fp32 outer sync vs streaming fragment-wise
    sync with quantized outer gradients, same run otherwise.  Single
    pool worker keeps the accumulation order (and hence the loss)
    deterministic; the comparison is bandwidth shape + quality, the
    wall-clock overlap is covered by the async-vs-barrier rows."""
    from repro.data import shard_documents
    from repro.infra.service import TrainingService

    cfg, key = s["cfg"], s["key"]
    W = 4
    docs, doms = s["docs"][:256], np.asarray(s["doms"][:256])
    ds = shard_documents(docs, doms % W, W)
    phases = 3 if quick else 6
    variants = {
        "burst_fp32": {},
        "stream_frag4_int8": dict(outer_fragments=4, fragment_stagger=1,
                                  comm_dtype="int8"),
    }
    runs = {}
    for name, over in variants.items():
        dcfg = DiPaCoConfig(levels=(2, 2), inner_steps=2, **over)
        with tempfile.TemporaryDirectory() as root:
            svc = TrainingService(
                cfg, dcfg, ds, key=key, ckpt_root=root,
                base_params=s["base"], batch_size=4, peak_lr=1e-3,
                warmup=10, total_steps=200, num_workers=1)
            svc.run(1, tau=2)             # warm the jit out of the timing
            # the warmup phase must not pollute the recorded comms
            # (peak is schedule-determined, but sends/totals are counts)
            svc.comm_stats.update(peak_sync_bytes=0, total_comm_bytes=0,
                                  sends=0)
            t0 = time.time()
            m = svc.run(phases, tau=2)
            dt = time.time() - t0
            runs[name] = (m, dict(svc.comm_stats), dt)
            svc.shutdown()
    mb, cb, dtb = runs["burst_fp32"]
    ms, cs, dts = runs["stream_frag4_int8"]
    peak_reduction = cb["peak_sync_bytes"] / max(cs["peak_sync_bytes"], 1)
    loss_ratio = ms["mean_loss"] / mb["mean_loss"]
    # the headline claims, gated in --smoke (run.py turns an exception
    # into a non-zero exit): streaming must cut the sync-instant
    # bandwidth burst >= 4x without hurting the phase loss > 1%
    assert peak_reduction >= 4.0, (
        f"peak comms reduction {peak_reduction:.2f}x < 4x "
        f"({cb['peak_sync_bytes']} -> {cs['peak_sync_bytes']} bytes)")
    assert loss_ratio <= 1.01, (
        f"streaming phase-loss regression {100 * (loss_ratio - 1):.2f}% "
        f"> 1% ({mb['mean_loss']:.4f} -> {ms['mean_loss']:.4f})")
    return [
        {"name": "outer_sync_burst_fp32",
         "us_per_call": dtb / phases * 1e6,
         "wall_s_per_phase": dtb / phases, "phases": phases,
         "peak_sync_bytes": cb["peak_sync_bytes"],
         "total_comm_bytes": cb["total_comm_bytes"],
         "sends": cb["sends"], "mean_loss": mb["mean_loss"]},
        {"name": "outer_sync_stream_frag4_int8",
         "us_per_call": dts / phases * 1e6,
         "wall_s_per_phase": dts / phases, "phases": phases,
         "peak_sync_bytes": cs["peak_sync_bytes"],
         "total_comm_bytes": cs["total_comm_bytes"],
         "sends": cs["sends"], "mean_loss": ms["mean_loss"],
         "peak_comms_reduction": peak_reduction,
         "total_comms_reduction":
             cb["total_comm_bytes"] / max(cs["total_comm_bytes"], 1),
         "loss_ratio_vs_burst": loss_ratio},
    ]


def run(quick: bool = True):
    s = common.setup(quick)
    rows = _executor_rows(s)
    rows += _async_vs_barrier_rows(s, quick)
    rows += _streaming_rows(s, quick)
    common.record_bench("outer_exec_async", rows,
                        path=common.BENCH_TRAIN_PATH)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
