"""Paper Table 3: routing more frequently at eval time (+early
stopping) closes the gap to the bigger dense model."""
from __future__ import annotations

import jax
import numpy as np

from repro.core.dipaco import DiPaCoTrainer
from repro.core.routing import (prefix_features,
                                train_discriminative_router)
from repro.core.routing.frequent import evaluate_rerouted
from repro.models.config import DiPaCoConfig
from . import common


def run(quick: bool = True):
    s = common.setup(quick)
    cfg, base, key = s["cfg"], s["base"], s["key"]
    phases, tau = (4, 10) if quick else (8, 25)
    P = 4
    ds, cents, feats = common.make_shards(s, P, method="kmeans")
    tr = DiPaCoTrainer(cfg, DiPaCoConfig(levels=(2, 2), inner_steps=tau,
                                         early_stopping=True), ds,
                       key=key, base_params=base, batch_size=8,
                       peak_lr=2e-3, warmup=10,
                       total_steps=phases * tau * 4)
    for _ in range(phases):
        tr.run_phase(tau)
    paths = [tr.path_params(p) for p in range(P)]
    paths_best = [tr.path_params(p, best=True) for p in range(P)]
    # discriminative router trained on router-data path scores (§7.2.1)
    from repro.core.routing.discriminative import score_documents
    rdocs = jax.numpy.asarray(s["router_docs"])
    scores = score_documents(paths, cfg, rdocs)
    targets = np.asarray(scores.argmax(axis=1))
    rfeats = prefix_features(base, cfg, rdocs, prefix_len=common.PREFIX)
    router = train_discriminative_router(jax.random.PRNGKey(2), rfeats,
                                         targets, P, steps=300)
    rows = []
    val = jax.numpy.asarray(s["val"])
    for early, label, plist in [(False, "no_es", paths),
                                (True, "es", paths_best)]:
        res = evaluate_rerouted(plist, cfg, router, base, val,
                                every=10_000)   # once per sequence
        rows.append({"name": f"route_once_{label}", "val_ppl": res["ppl"],
                     "switch_rate": 0.0, "us_per_call": 0.0})
    for every in ([16, 8] if quick else [32, 16, 8, 4]):
        res = evaluate_rerouted(paths_best, cfg, router, base, val,
                                every=every)
        rows.append({"name": f"route_every_{every}_es",
                     "val_ppl": res["ppl"],
                     "switch_rate": res["switch_rate"],
                     "us_per_call": 0.0})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
