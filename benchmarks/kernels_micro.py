"""Kernel microbenchmarks: µs/call (interpret mode on CPU — correctness
path; real-TPU timing is the deploy target) + max |err| vs ref oracle."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6, out


def run(quick: bool = True):
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    us, out = _time(ops.flash_attention, q, k, v, causal=True,
                    block_q=64, block_k=64, interpret=True)
    err = float(jnp.abs(out - ref.flash_attention_ref(q, k, v)).max())
    rows.append({"name": "kernel_flash_attention_256", "us_per_call": us,
                 "max_err": err})

    from repro.kernels.flash_attention_bwd import flash_attention_trainable

    def fwd_bwd(q_, k_, v_):
        return jax.grad(lambda a, b, c: jnp.sum(flash_attention_trainable(
            a, b, c, True, None, 64, 64, True)))(q_, k_, v_)

    us, g = _time(fwd_bwd, q, k, v, reps=1)
    rows.append({"name": "kernel_flash_attention_bwd_256",
                 "us_per_call": us, "max_err": 0.0})

    z = jax.random.normal(ks[3], (2048, 64))
    c = jax.random.normal(ks[4], (16, 64))
    us, (a, d2) = _time(ops.router_assign, z, c, interpret=True)
    ea, _ = ref.router_assign_ref(z, c)
    rows.append({"name": "kernel_router_assign_2048x16",
                 "us_per_call": us,
                 "max_err": float((a != ea).mean())})

    x = jax.random.normal(ks[5], (1, 256, 2, 32)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[6], (1, 256, 2)))
    a_ = -jnp.exp(jax.random.normal(ks[7], (2,)) * 0.3)
    bm = jax.random.normal(ks[5], (1, 256, 2, 16)) * 0.5
    cm = jax.random.normal(ks[6], (1, 256, 2, 16)) * 0.5
    us, y = _time(ops.ssd_scan, x, dt, a_, bm, cm, chunk=64,
                  interpret=True)
    err = float(jnp.abs(y - ref.ssd_scan_ref(x, dt, a_, bm, cm,
                                             chunk=64)).max())
    rows.append({"name": "kernel_ssd_scan_256", "us_per_call": us,
                 "max_err": err})

    xe = jax.random.normal(ks[0], (4, 128, 256))
    w = jax.random.normal(ks[1], (4, 256, 128))
    us, g = _time(ops.expert_gemm, xe, w, block_m=64, block_n=64,
                  block_k=128, interpret=True)
    err = float(jnp.abs(g - ref.expert_gemm_ref(xe, w)).max())
    rows.append({"name": "kernel_expert_gemm_4x128", "us_per_call": us,
                 "max_err": err})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
