"""Serving throughput: continuous batching vs the one-shot batch loop.

A mixed-length Poisson arrival trace is served three times on the wall
clock:

* one-shot baseline: whenever requests have arrived, take them as one
  batch (grouped by prompt length — the old engine needs rectangular
  batches), run ``generate`` to completion, only then admit the next
  batch; prefill is the old token-by-token replay.
* continuous batching, PR-1 configuration: per-island decode loop (one
  jit dispatch per path per tick) and batch-1 exact-length prefill.
* continuous batching, current configuration: stacked-island decode
  (one vmapped dispatch advances every island) + length-bucketed
  batched prefill.

Reports requests/s, p50/p95/p99 request latency and (for the
continuous engines) p50/p95 time-to-first-token for each, the speedups,
verifies greedy outputs are token-identical across all engines, and
records the rows into ``BENCH_decode.json``.  Offered load exceeds the
one-shot capacity so req/s measures service capacity, not the Poisson
arrival rate.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import api
from repro.serving import (ContinuousBatchingEngine, PathServingEngine,
                           Request, poisson_trace, prefix_hash_router)

from .common import record_bench


def _percentiles(lat):
    lat = np.asarray(lat)
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 95)),
            float(np.percentile(lat, 99)))


def _serve_oneshot(engine, trace, max_new):
    """Blocking batch loop: admit everything that has arrived, generate,
    repeat.  Returns (tokens_by_rid, latency_by_rid, makespan)."""
    trace = sorted(trace, key=lambda r: r.arrival)
    i, n = 0, len(trace)
    tokens, latency = {}, {}
    t0 = time.perf_counter()
    while i < n:
        now = time.perf_counter() - t0
        batch = []
        while i < n and trace[i].arrival <= now:
            batch.append(trace[i])
            i += 1
        if not batch:
            time.sleep(min(1e-3, trace[i].arrival - now))
            continue
        by_len = {}
        for r in batch:
            by_len.setdefault(len(r.prompt), []).append(r)
        for _, group in by_len.items():
            res = engine.generate(np.stack([r.prompt for r in group]),
                                  max_new=max_new)
            # drain async device work before reading the clock, so
            # req/s and latencies aren't skewed by pending dispatches
            jax.block_until_ready(engine.device_state())
            done = time.perf_counter() - t0
            for j, r in enumerate(group):
                tokens[r.rid] = res.tokens[j]
                latency[r.rid] = done - r.arrival
    jax.block_until_ready(engine.device_state())
    return tokens, latency, time.perf_counter() - t0


def run(quick: bool = True):
    # offered load must exceed every engine's service capacity (the
    # continuous engines sustain ~100 req/s at this scale, the one-shot
    # ~10) so requests/s measures capacity, not the Poisson arrival rate
    n, rate = (48, 300.0) if quick else (128, 300.0)
    max_new = 12 if quick else 24
    prompt_lens = (16, 24, 32)
    cache_len = max(prompt_lens) + max_new
    # float32 smoke config: greedy argmax must be numerically stable so
    # the token-identity check is meaningful
    cfg = get_smoke_config("dipaco-150m").replace(route_prefix_len=8)
    key = jax.random.PRNGKey(0)
    # many small islands with few slots each — the regime the paper
    # serves (§2.2/§2.6) and where per-island dispatch overhead bites
    num_paths, slots = (8, 4) if quick else (8, 8)
    paths = [api.init_model(jax.random.fold_in(key, p), cfg)[0]
             for p in range(num_paths)]

    # deterministic prompt-hash routing spreads the trace over all
    # islands identically for every engine (keeps the token-identity
    # check meaningful without training a router)
    hash_route = prefix_hash_router(num_paths)

    def make_trace():
        return poisson_trace(n, rate=rate, prompt_lens=prompt_lens,
                             max_new=max_new, vocab_size=cfg.vocab_size,
                             seed=7)

    oneshot = PathServingEngine(cfg, paths, cache_len=cache_len,
                                route_fn=hash_route)
    cont_pr1 = ContinuousBatchingEngine(
        cfg, paths, cache_len=cache_len, slots_per_path=slots,
        stacked=False, bucketed_prefill=False, route_fn=hash_route)
    # buckets matched to the trace's length distribution (how a
    # deployment would choose them); compile cache stays bounded by
    # the bucket set either way
    cont = ContinuousBatchingEngine(cfg, paths, cache_len=cache_len,
                                    slots_per_path=slots,
                                    prefill_buckets=prompt_lens,
                                    route_fn=hash_route)

    # warmup: compile every (batch, length) prefill/decode variant off
    # the clock
    warm = [Request(rid=10_000 + i, prompt=np.full(ln, 1, np.int32),
                    max_new=2, arrival=0.0)
            for i, ln in enumerate(prompt_lens)]
    for eng in (cont_pr1, cont):
        eng.warmup()   # bounded (bucket, batch) prefill + decode set
        eng.serve_trace([Request(r.rid, r.prompt, r.max_new, 0.0)
                         for r in warm])
        eng.scheduler.stats = type(eng.scheduler.stats)()  # drop warmup
    for ln in prompt_lens:
        oneshot.generate(np.full((1, ln), 1, np.int32), max_new=2)

    def _serve_cont_once(eng):
        # per-trial stats so the recorded backpressure_ticks describe
        # one trace, not the sum over trials
        eng.scheduler.stats = type(eng.scheduler.stats)()
        t0 = time.perf_counter()
        fins = eng.serve_trace(make_trace(), realtime=True)
        jax.block_until_ready(eng.device_state())
        span_wall = time.perf_counter() - t0
        span = max(max(f.finished_at for f in fins), span_wall)
        return ({f.rid: f.tokens for f in fins},
                {f.rid: f.latency for f in fins}, span,
                {f.rid: f.ttft for f in fins})

    # interleaved trials + median span: wall-clock noise on a shared
    # CPU swings whole seconds, so pair the engines in time and take a
    # robust summary rather than a single (or best-of) measurement
    span_1s = []
    for _ in range(3):
        tok_1, lat_1, s1 = _serve_oneshot(oneshot, make_trace(), max_new)
        span_1s.append(s1)
    span_1 = float(np.median(span_1s))
    trials = 5
    res_p, res_c = [], []
    for _ in range(trials):
        res_p.append(_serve_cont_once(cont_pr1))
        res_c.append(_serve_cont_once(cont))
    tok_p, lat_p, _, ttft_p = res_p[-1]
    tok_c, lat_c, _, ttft_c = res_c[-1]
    span_p = float(np.median([r[2] for r in res_p]))
    span_c = float(np.median([r[2] for r in res_c]))

    match = all((tok_c[r] == tok_1[r]).all()
                and (tok_p[r] == tok_1[r]).all() for r in tok_1)
    if not match:
        raise RuntimeError(
            "continuous-batching greedy outputs diverged from the "
            "one-shot engine")
    rps_1, rps_p, rps_c = n / span_1, n / span_p, n / span_c
    p50_1, p95_1, p99_1 = _percentiles(list(lat_1.values()))
    p50_p, p95_p, p99_p = _percentiles(list(lat_p.values()))
    p50_c, p95_c, p99_c = _percentiles(list(lat_c.values()))
    # time-to-first-token (prefill + queueing): the latency users feel
    # on streaming responses; the one-shot engine has no per-request
    # first-token timestamp (the whole batch blocks to completion)
    t50_p, t95_p, _ = _percentiles(list(ttft_p.values()))
    t50_c, t95_c, _ = _percentiles(list(ttft_c.values()))
    rows = [
        {"name": "serving_oneshot", "us_per_call": span_1 / n * 1e6,
         "req_per_s": rps_1, "p50_s": p50_1, "p95_s": p95_1,
         "p99_s": p99_1, "n": n},
        {"name": "serving_continuous_pr1", "us_per_call": span_p / n * 1e6,
         "req_per_s": rps_p, "p50_s": p50_p, "p95_s": p95_p,
         "p99_s": p99_p, "ttft_p50_s": t50_p, "ttft_p95_s": t95_p,
         "n": n, "stacked": 0, "bucketed_prefill": 0,
         "backpressure_ticks":
             cont_pr1.scheduler.stats.backpressure_ticks},
        {"name": "serving_continuous", "us_per_call": span_c / n * 1e6,
         "req_per_s": rps_c, "p50_s": p50_c, "p95_s": p95_c,
         "p99_s": p99_c, "ttft_p50_s": t50_c, "ttft_p95_s": t95_c,
         "n": n, "stacked": int(cont.stacked),
         "bucketed_prefill": int(cont.bucketed),
         "backpressure_ticks":
             cont.scheduler.stats.backpressure_ticks},
        {"name": "serving_speedup", "us_per_call": 0.0,
         "req_per_s_ratio": rps_c / rps_1,
         "stacked_bucketed_over_pr1": rps_c / rps_p,
         "tokens_identical": int(match)},
    ]
    record_bench("serving_throughput", rows)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
