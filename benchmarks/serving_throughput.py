"""Serving throughput: continuous batching vs the one-shot batch loop.

A mixed-length Poisson arrival trace is served three times on the wall
clock:

* one-shot baseline: whenever requests have arrived, take them as one
  batch (grouped by prompt length — the old engine needs rectangular
  batches), run ``generate`` to completion, only then admit the next
  batch; prefill is the old token-by-token replay.
* continuous batching, PR-1 configuration: per-island decode loop (one
  jit dispatch per path per tick) and batch-1 exact-length prefill.
* continuous batching, current configuration: stacked-island decode
  (one vmapped dispatch advances every island) + length-bucketed
  batched prefill.

Reports requests/s, p50/p95/p99 request latency and (for the
continuous engines) p50/p95 time-to-first-token for each, the speedups,
verifies greedy outputs are token-identical across all engines, and
records the rows into ``BENCH_decode.json``.  Offered load exceeds the
one-shot capacity so req/s measures service capacity, not the Poisson
arrival rate.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import api
from repro.serving import (ContinuousBatchingEngine, EngineOptions,
                           PathServingEngine, Request, ServingFleet,
                           poisson_trace, prefix_hash_router)

from .common import make_telemetry, record_bench


def _percentiles(lat):
    lat = np.asarray(lat)
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 95)),
            float(np.percentile(lat, 99)))


def _serve_oneshot(engine, trace, max_new):
    """Blocking batch loop: admit everything that has arrived, generate,
    repeat.  Returns (tokens_by_rid, latency_by_rid, makespan)."""
    trace = sorted(trace, key=lambda r: r.arrival)
    i, n = 0, len(trace)
    tokens, latency = {}, {}
    t0 = time.perf_counter()
    while i < n:
        now = time.perf_counter() - t0
        batch = []
        while i < n and trace[i].arrival <= now:
            batch.append(trace[i])
            i += 1
        if not batch:
            time.sleep(min(1e-3, trace[i].arrival - now))
            continue
        by_len = {}
        for r in batch:
            by_len.setdefault(len(r.prompt), []).append(r)
        for _, group in by_len.items():
            res = engine.generate(np.stack([r.prompt for r in group]),
                                  max_new=max_new)
            # drain async device work before reading the clock, so
            # req/s and latencies aren't skewed by pending dispatches
            jax.block_until_ready(engine.device_state())
            done = time.perf_counter() - t0
            for j, r in enumerate(group):
                tokens[r.rid] = res.tokens[j]
                latency[r.rid] = done - r.arrival
    jax.block_until_ready(engine.device_state())
    return tokens, latency, time.perf_counter() - t0


def run(quick: bool = True):
    # offered load must exceed every engine's service capacity (the
    # continuous engines sustain ~100 req/s at this scale, the one-shot
    # ~10) so requests/s measures capacity, not the Poisson arrival rate
    n, rate = (48, 300.0) if quick else (128, 300.0)
    max_new = 12 if quick else 24
    prompt_lens = (16, 24, 32)
    cache_len = max(prompt_lens) + max_new
    # float32 smoke config: greedy argmax must be numerically stable so
    # the token-identity check is meaningful
    cfg = get_smoke_config("dipaco-150m").replace(route_prefix_len=8)
    key = jax.random.PRNGKey(0)
    # many small islands with few slots each — the regime the paper
    # serves (§2.2/§2.6) and where per-island dispatch overhead bites
    num_paths, slots = (8, 4) if quick else (8, 8)
    paths = [api.init_model(jax.random.fold_in(key, p), cfg)[0]
             for p in range(num_paths)]

    # deterministic prompt-hash routing spreads the trace over all
    # islands identically for every engine (keeps the token-identity
    # check meaningful without training a router)
    hash_route = prefix_hash_router(num_paths)

    def make_trace():
        return poisson_trace(n, rate=rate, prompt_lens=prompt_lens,
                             max_new=max_new, vocab_size=cfg.vocab_size,
                             seed=7)

    oneshot = PathServingEngine(cfg, paths, options=EngineOptions(
        cache_len=cache_len, route_fn=hash_route))
    cont_pr1 = ContinuousBatchingEngine(cfg, paths, options=EngineOptions(
        cache_len=cache_len, slots_per_path=slots, stacked=False,
        bucketed_prefill=False, route_fn=hash_route))
    # buckets matched to the trace's length distribution (how a
    # deployment would choose them); compile cache stays bounded by
    # the bucket set either way
    cont = ContinuousBatchingEngine(cfg, paths, options=EngineOptions(
        cache_len=cache_len, slots_per_path=slots,
        prefill_buckets=prompt_lens, route_fn=hash_route))

    # warmup: compile every (batch, length) prefill/decode variant off
    # the clock
    warm = [Request(rid=10_000 + i, prompt=np.full(ln, 1, np.int32),
                    max_new=2, arrival=0.0)
            for i, ln in enumerate(prompt_lens)]
    for eng in (cont_pr1, cont):
        eng.warmup()   # bounded (bucket, batch) prefill + decode set
        eng.serve_trace([Request(r.rid, r.prompt, r.max_new, 0.0)
                         for r in warm])
        eng.scheduler.stats = type(eng.scheduler.stats)()  # drop warmup
    for ln in prompt_lens:
        oneshot.generate(np.full((1, ln), 1, np.int32), max_new=2)

    def _serve_cont_once(eng):
        # per-trial stats so the recorded backpressure_ticks describe
        # one trace, not the sum over trials
        eng.scheduler.stats = type(eng.scheduler.stats)()
        t0 = time.perf_counter()
        fins = eng.serve_trace(make_trace(), realtime=True)
        jax.block_until_ready(eng.device_state())
        span_wall = time.perf_counter() - t0
        span = max(max(f.finished_at for f in fins), span_wall)
        return ({f.rid: f.tokens for f in fins},
                {f.rid: f.latency for f in fins}, span,
                {f.rid: f.ttft for f in fins})

    # interleaved trials + median span: wall-clock noise on a shared
    # CPU swings whole seconds, so pair the engines in time and take a
    # robust summary rather than a single (or best-of) measurement
    span_1s = []
    for _ in range(3):
        tok_1, lat_1, s1 = _serve_oneshot(oneshot, make_trace(), max_new)
        span_1s.append(s1)
    span_1 = float(np.median(span_1s))
    trials = 5
    res_p, res_c = [], []
    for _ in range(trials):
        res_p.append(_serve_cont_once(cont_pr1))
        res_c.append(_serve_cont_once(cont))
    tok_p, lat_p, _, ttft_p = res_p[-1]
    tok_c, lat_c, _, ttft_c = res_c[-1]
    span_p = float(np.median([r[2] for r in res_p]))
    span_c = float(np.median([r[2] for r in res_c]))

    match = all((tok_c[r] == tok_1[r]).all()
                and (tok_p[r] == tok_1[r]).all() for r in tok_1)
    if not match:
        raise RuntimeError(
            "continuous-batching greedy outputs diverged from the "
            "one-shot engine")
    rps_1, rps_p, rps_c = n / span_1, n / span_p, n / span_c
    p50_1, p95_1, p99_1 = _percentiles(list(lat_1.values()))
    p50_p, p95_p, p99_p = _percentiles(list(lat_p.values()))
    p50_c, p95_c, p99_c = _percentiles(list(lat_c.values()))
    # time-to-first-token (prefill + queueing): the latency users feel
    # on streaming responses; the one-shot engine has no per-request
    # first-token timestamp (the whole batch blocks to completion)
    t50_p, t95_p, _ = _percentiles(list(ttft_p.values()))
    t50_c, t95_c, _ = _percentiles(list(ttft_c.values()))
    rows = [
        {"name": "serving_oneshot", "us_per_call": span_1 / n * 1e6,
         "req_per_s": rps_1, "p50_s": p50_1, "p95_s": p95_1,
         "p99_s": p99_1, "n": n},
        {"name": "serving_continuous_pr1", "us_per_call": span_p / n * 1e6,
         "req_per_s": rps_p, "p50_s": p50_p, "p95_s": p95_p,
         "p99_s": p99_p, "ttft_p50_s": t50_p, "ttft_p95_s": t95_p,
         "n": n, "stacked": 0, "bucketed_prefill": 0,
         "backpressure_ticks":
             cont_pr1.scheduler.stats.backpressure_ticks},
        {"name": "serving_continuous", "us_per_call": span_c / n * 1e6,
         "req_per_s": rps_c, "p50_s": p50_c, "p95_s": p95_c,
         "p99_s": p99_c, "ttft_p50_s": t50_c, "ttft_p95_s": t95_c,
         "n": n, "stacked": int(cont.stacked),
         "bucketed_prefill": int(cont.bucketed),
         "backpressure_ticks":
             cont.scheduler.stats.backpressure_ticks},
        {"name": "serving_speedup", "us_per_call": 0.0,
         "req_per_s_ratio": rps_c / rps_1,
         "stacked_bucketed_over_pr1": rps_c / rps_p,
         "tokens_identical": int(match)},
    ]
    record_bench("serving_throughput", rows)
    return rows


# ---------------------------------------------------------------------------
# Serving fleet (multi-process path-affinity front door)
# ---------------------------------------------------------------------------

def _register_v2(reg, cfg, dcfg, base, db):
    """Mint a second registry version from slightly perturbed modules
    (what one outer phase would publish), so the hot-swap check has a
    real version transition to move the fleet across."""
    from repro.core.module_store import ModuleStore
    from repro.core.partition import make_partition
    _, axes = api.init_model(jax.random.PRNGKey(0), cfg)
    bumped = jax.tree_util.tree_map(lambda x: x * (1.0 + 1e-2), base)
    store = ModuleStore(bumped, axes,
                        make_partition(dcfg, cfg.pattern_repeats))
    rows = {}
    for mid in reg.module_ids:
        tree = store.shared if mid == (-1, -1) \
            else store.module_params(*mid)
        rows[mid] = db.write({"params": tree}, path_id=0, phase=1,
                             step=1, kind="module", level=mid[0],
                             expert=mid[1])
    return reg.register(rows, note="fleet bench v2")


def run_fleet(quick: bool = True):
    """Serving-fleet scenario: N engine *processes* behind the
    path-affinity front door vs one engine with the same per-path slot
    budget, serving the same priority-mixed Poisson trace.

    Reports req/s for both, p99 latency and p50/p95 TTFT per priority
    class, verifies the fleet's greedy tokens are identical to the
    single engine's (fp32 smoke config — preemption and prefix caching
    are identity-preserving by construction), and hot-swaps the whole
    fleet with one ``registry.promote``.  Speedup gate is adaptive: on
    a multi-core host the fleet must beat the single engine by >= 1.05x
    req/s; this CI container pins everything to one core, where N
    processes time-slice a single CPU and the honest bound is a noise
    floor (>= 0.3x, the PR-6 mesh-speedup precedent).  The raw ratio is
    recorded either way so multi-core runs regress on the real number.
    """
    import os
    import tempfile

    from repro.deploy import DeploymentRegistry
    from repro.infra import CheckpointDB
    from repro.models.config import DiPaCoConfig
    from repro.serving import (PRIO_HIGH, PRIO_PREEMPTIBLE, PRIO_STANDARD,
                               EngineOptions)

    n, rate = (32, 120.0) if quick else (96, 200.0)
    max_new = 8 if quick else 16
    prompt_lens = (16, 24)
    cache_len = max(prompt_lens) + max_new
    size = 2 if quick else 4
    cfg = get_smoke_config("dipaco-150m").replace(route_prefix_len=8)
    dcfg = DiPaCoConfig(levels=(2, 2))          # 4 path islands
    base, _ = api.init_model(jax.random.PRNGKey(0), cfg)
    hash_route = prefix_hash_router(4)

    def make_trace():
        t = poisson_trace(n, rate=rate, prompt_lens=prompt_lens,
                          max_new=max_new, vocab_size=cfg.vocab_size,
                          seed=13,
                          priorities=((PRIO_HIGH, PRIO_STANDARD,
                                       PRIO_PREEMPTIBLE),
                                      (0.25, 0.5, 0.25)))
        for r in t:   # pre-route: identical assignment for both engines
            r.path = hash_route(r.prompt)
        return t

    with tempfile.TemporaryDirectory() as root:
        # children rebuild this registry from (cfg, dcfg, root, seed=0),
        # so base_params must be the seed-0 init for payload identity
        reg = DeploymentRegistry(cfg, dcfg, os.path.join(root, "deploy"),
                                 key=jax.random.PRNGKey(0),
                                 base_params=base)
        m1 = reg.register(note="fleet bench v1")
        reg.promote(m1.version)
        opts = EngineOptions(registry=reg, cache_len=cache_len,
                             slots_per_path=2,
                             prefill_buckets=prompt_lens, prefix_cache=64)

        single = ContinuousBatchingEngine(cfg, options=opts)
        single.warmup()
        single.serve_trace([Request(rid=10_000 + i,
                                    prompt=np.full(ln, 1, np.int32),
                                    max_new=2, arrival=0.0)
                            for i, ln in enumerate(prompt_lens)])
        single.scheduler.stats = type(single.scheduler.stats)()
        # best-of-2 spans: both serves are post-warmup, min is the
        # standard scheduler-noise reducer on a shared CI host
        span_1s = []
        for _ in range(2):
            t0 = time.perf_counter()
            fins_1 = single.serve_trace(make_trace(), realtime=True)
            jax.block_until_ready(single.device_state())
            span_1s.append(max(time.perf_counter() - t0,
                               max(f.finished_at for f in fins_1)))
        span_1 = min(span_1s)

        from repro.serving import ServingFleet
        tel = make_telemetry("fleet_serve")
        with ServingFleet(cfg, size=size, options=opts,
                          backend="process", seed=0,
                          warmup=True, telemetry=tel) as fleet:
            span_fs = []
            for _ in range(2):
                t0 = time.perf_counter()
                fins_f = fleet.serve_trace(make_trace())
                span_fs.append(max(time.perf_counter() - t0,
                                   max(f.finished_at for f in fins_f)))
            span_f = min(span_fs)
            # one promote hot-swaps every member (the cross-process
            # SERVING pointer poll inside each child's engine tick)
            db = CheckpointDB(os.path.join(root, "db"))
            m2 = _register_v2(reg, cfg, dcfg, base, db)
            t_swap = time.perf_counter()
            reg.promote(m2.version)
            fleet.wait_version(m2.version, timeout=300.0)
            swap_s = time.perf_counter() - t_swap
            routed = fleet.stats["routed"]
            rebalances = fleet.stats["rebalances"]
        tel.close()

    if len(fins_f) != n or len(fins_1) != n:
        raise RuntimeError(f"fleet returned {len(fins_f)}/{n}, "
                           f"single {len(fins_1)}/{n} requests")
    tok_1 = {f.rid: f.tokens for f in fins_1}
    match = all(np.array_equal(f.tokens, tok_1[f.rid]) for f in fins_f)
    if not match:
        raise RuntimeError("fleet greedy outputs diverged from the "
                           "single-engine baseline")
    rps_1, rps_f = n / span_1, n / span_f
    ratio = rps_f / rps_1
    cores = os.cpu_count() or 1
    floor = 1.05 if cores > size else 0.3
    if ratio < floor:
        raise RuntimeError(
            f"fleet speedup {ratio:.2f}x below the {floor}x floor "
            f"({cores} cores, {size} members)")

    rows = [
        {"name": "fleet_single_baseline", "us_per_call": span_1 / n * 1e6,
         "req_per_s": rps_1, "n": n},
        {"name": "fleet_process", "us_per_call": span_f / n * 1e6,
         "req_per_s": rps_f, "members": size, "routed": routed,
         "rebalances": rebalances, "n": n},
        {"name": "fleet_speedup", "us_per_call": 0.0,
         "req_per_s_ratio": ratio, "gate_floor": floor,
         "tokens_identical": int(match), "hot_swap_s": swap_s,
         "swap_version": m2.version},
    ]
    prio_names = {PRIO_HIGH: "high", PRIO_STANDARD: "standard",
                  PRIO_PREEMPTIBLE: "preemptible"}
    by_prio = {}
    for f in fins_f:
        by_prio.setdefault(f.priority, []).append(f)
    for c in sorted(by_prio):
        fl = by_prio[c]
        lat = [f.latency for f in fl]
        tt = [f.ttft for f in fl]
        rows.append({
            "name": f"fleet_prio_{prio_names[c]}",
            "us_per_call": float(np.mean(lat)) * 1e6,
            "p99_s": _percentiles(lat)[2],
            "ttft_p50_s": _percentiles(tt)[0],
            "ttft_p95_s": _percentiles(tt)[1],
            "n": len(fl)})
    record_bench("serving_fleet", rows, trace=tel.path)
    return rows


if __name__ == "__main__":
    import sys
    scenario = run_fleet if "--fleet" in sys.argv else run
    for r in scenario():
        print(r)
