"""Serving throughput: continuous batching vs the one-shot batch loop.

A mixed-length Poisson arrival trace is served twice on the wall clock:

* one-shot baseline: whenever requests have arrived, take them as one
  batch (grouped by prompt length — the old engine needs rectangular
  batches), run ``generate`` to completion, only then admit the next
  batch; prefill is the old token-by-token replay.
* continuous batching: requests are admitted into slot arenas as they
  arrive; each tick prefills admissions in one forward while decoding
  all in-flight requests.

Reports requests/s and p50/p99 request latency for both, the speedup,
and verifies greedy outputs are token-identical between engines.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import api
from repro.serving import (ContinuousBatchingEngine, PathServingEngine,
                           Request, poisson_trace)


def _percentiles(lat):
    lat = np.asarray(lat)
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def _serve_oneshot(engine, trace, max_new):
    """Blocking batch loop: admit everything that has arrived, generate,
    repeat.  Returns (tokens_by_rid, latency_by_rid, makespan)."""
    trace = sorted(trace, key=lambda r: r.arrival)
    i, n = 0, len(trace)
    tokens, latency = {}, {}
    t0 = time.perf_counter()
    while i < n:
        now = time.perf_counter() - t0
        batch = []
        while i < n and trace[i].arrival <= now:
            batch.append(trace[i])
            i += 1
        if not batch:
            time.sleep(min(1e-3, trace[i].arrival - now))
            continue
        by_len = {}
        for r in batch:
            by_len.setdefault(len(r.prompt), []).append(r)
        for _, group in by_len.items():
            res = engine.generate(np.stack([r.prompt for r in group]),
                                  max_new=max_new)
            done = time.perf_counter() - t0
            for j, r in enumerate(group):
                tokens[r.rid] = res.tokens[j]
                latency[r.rid] = done - r.arrival
    return tokens, latency, time.perf_counter() - t0


def run(quick: bool = True):
    # offered load must exceed the one-shot engine's capacity (~8 req/s
    # at this scale) so requests/s measures service capacity, not the
    # arrival rate
    n, rate = (24, 40.0) if quick else (96, 40.0)
    max_new = 12 if quick else 24
    prompt_lens = (16, 24, 32)
    cache_len = max(prompt_lens) + max_new
    # float32 smoke config: greedy argmax must be numerically stable so
    # the token-identity check is meaningful
    cfg = get_smoke_config("dipaco-150m").replace(route_prefix_len=8)
    key = jax.random.PRNGKey(0)
    paths = [api.init_model(jax.random.fold_in(key, p), cfg)[0]
             for p in range(2)]

    def make_trace():
        return poisson_trace(n, rate=rate, prompt_lens=prompt_lens,
                             max_new=max_new, vocab_size=cfg.vocab_size,
                             seed=7)

    oneshot = PathServingEngine(cfg, paths, cache_len=cache_len)
    cont = ContinuousBatchingEngine(cfg, paths, cache_len=cache_len,
                                    slots_per_path=8 if quick else 16)

    # warmup: compile every (batch, length) prefill/decode variant off
    # the clock
    warm = [Request(rid=10_000 + i, prompt=np.full(ln, 1, np.int32),
                    max_new=2, arrival=0.0)
            for i, ln in enumerate(prompt_lens)]
    cont.serve_trace([Request(r.rid, r.prompt, r.max_new, 0.0)
                      for r in warm])
    for ln in prompt_lens:
        oneshot.generate(np.full((1, ln), 1, np.int32), max_new=2)
    cont.scheduler.stats = type(cont.scheduler.stats)()  # drop warmup stats

    tok_1, lat_1, span_1 = _serve_oneshot(oneshot, make_trace(), max_new)
    fins = cont.serve_trace(make_trace(), realtime=True)
    tok_c = {f.rid: f.tokens for f in fins}
    lat_c = {f.rid: f.latency for f in fins}
    span_c = max(f.finished_at for f in fins)

    match = all((tok_c[r] == tok_1[r]).all() for r in tok_1)
    if not match:
        raise RuntimeError(
            "continuous-batching greedy outputs diverged from the "
            "one-shot engine")
    rps_1, rps_c = n / span_1, n / span_c
    p50_1, p99_1 = _percentiles(list(lat_1.values()))
    p50_c, p99_c = _percentiles(list(lat_c.values()))
    return [
        {"name": "serving_oneshot", "us_per_call": span_1 / n * 1e6,
         "req_per_s": rps_1, "p50_s": p50_1, "p99_s": p99_1,
         "n": n},
        {"name": "serving_continuous", "us_per_call": span_c / n * 1e6,
         "req_per_s": rps_c, "p50_s": p50_c, "p99_s": p99_c,
         "n": n, "backpressure_ticks":
             cont.scheduler.stats.backpressure_ticks},
        {"name": "serving_speedup", "us_per_call": 0.0,
         "req_per_s_ratio": rps_c / rps_1,
         "tokens_identical": int(match)},
    ]


if __name__ == "__main__":
    for r in run():
        print(r)
