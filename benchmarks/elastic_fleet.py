"""Elastic fleet robustness (§3.4): stable vs lossy vs flapping fleets.

Three identical miniature training runs through ``TrainingService``:

``fleet_stable``
    The reference: all four shards, calm transport.

``fleet_loss30_recovered``
    30% of the fleet is killed *mid-phase* (``ChaosController``
    ``kill_frac``), the survivors train on with resized quorums, the
    victims rejoin at the end and catch up.  Gated under ``--smoke``:
    the final-phase mean loss must land within 2% of the stable
    fleet's (the ISSUE acceptance bar) — elasticity must cost noise,
    not convergence.  ``recovery_wall_s`` is the recovered-phase
    latency: the wall-clock of the catch-up phase after the rejoin.

``fleet_flapping_faulty``
    One shard flaps (leave/join every phase boundary) while the
    transport drops/duplicates/corrupts sends on a seeded schedule —
    the full chaos layer at once.  Records the retry overhead (retries
    per goodput send, burned retry bytes) separately from goodput;
    gated on the chaos actually firing (retries > 0, epochs > 0) and
    the run still converging to a finite loss.

Results are recorded to ``BENCH_train.json`` under ``elastic_fleet``.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time

import numpy as np

from repro.data import shard_documents
from repro.infra import ChaosController, TrainingService
from repro.models.config import DiPaCoConfig
from . import common

_W = 4


def _dataset(s):
    docs, doms = s["docs"][:256], np.asarray(s["doms"][:256])
    return shard_documents(docs, doms % _W, _W)


def _service(s, ds, root, dcfg, **over):
    kw = dict(key=s["key"], base_params=s["base"], batch_size=4,
              peak_lr=1e-3, warmup=10, total_steps=200, num_workers=1)
    kw.update(over)
    return TrainingService(s["cfg"], dcfg, ds, ckpt_root=root, **kw)


def _stable_row(s, ds, dcfg, phases, tel):
    tel.instant("bench.section", section="fleet_stable")
    with tempfile.TemporaryDirectory() as root:
        with _service(s, ds, root, dcfg, telemetry=tel) as svc:
            svc.run(1, tau=2)              # warm the jit out of the timing
            t0 = time.time()
            m = svc.run(phases, tau=2)
            dt = time.time() - t0
    return {"name": "fleet_stable", "us_per_call": dt / phases * 1e6,
            "wall_s_per_phase": dt / phases, "phases": phases,
            "mean_loss": m["mean_loss"],
            "outer_updates": m["outer_updates"],
            "members": len(m["members"])}


def _loss30_row(s, ds, dcfg, phases, stable_loss, tel):
    tel.instant("bench.section", section="fleet_loss30_recovered")
    with tempfile.TemporaryDirectory() as root:
        with _service(s, ds, root, dcfg, telemetry=tel) as svc:
            svc.run(1, tau=2)
            chaos = ChaosController(svc, [
                {"phase": 1, "action": "kill_frac", "frac": 0.3,
                 "when": "mid"}], seed=11)
            t0 = time.time()
            chaos.run(phases - 1, tau=2)   # degraded fleet trains on
            dt_degraded = time.time() - t0
            evicted = sorted(set(range(_W)) - svc.members)
            assert evicted, "kill_frac(0.3) evicted nobody"
            svc.fleet.join(evicted)
            t0 = time.time()
            m = svc.run(1, tau=2)          # victims catch up + final phase
            recovery = time.time() - t0
    delta_pct = 100.0 * abs(m["mean_loss"] - stable_loss) / stable_loss
    # the ISSUE acceptance gate: losing 30% of the workers mid-phase
    # must not cost more than 2% final loss vs the stable fleet
    assert delta_pct <= 2.0, (
        f"30%-loss fleet diverged from stable: mean_loss "
        f"{m['mean_loss']:.4f} vs {stable_loss:.4f} "
        f"({delta_pct:.2f}% > 2%)")
    assert len(m["members"]) == _W         # the fleet healed
    return {"name": "fleet_loss30_recovered",
            "us_per_call": dt_degraded / max(phases - 1, 1) * 1e6,
            "wall_s_per_phase": dt_degraded / max(phases - 1, 1),
            "phases": phases, "mean_loss": m["mean_loss"],
            "loss_delta_pct": delta_pct, "recovery_wall_s": recovery,
            "evicted": len(evicted), "fleet_epoch": m["fleet_epoch"],
            "outer_updates": m["outer_updates"]}


def _flapping_row(s, ds, dcfg, phases, stable_loss, tel):
    tel.instant("bench.section", section="fleet_flapping_faulty")
    noisy = dataclasses.replace(
        dcfg, transport_retries=12,
        transport_faults={"seed": 5, "drop": 0.15, "dup": 0.1,
                          "corrupt": 0.05, "delay": 0.05,
                          "delay_s": 0.0})
    events = []
    for p in range(1, phases, 2):          # flap shard 3 every 2 phases
        events.append({"phase": p, "action": "leave", "shards": [3]})
        events.append({"phase": p + 1, "action": "join", "shards": [3]})
    with tempfile.TemporaryDirectory() as root:
        with _service(s, ds, root, noisy, telemetry=tel) as svc:
            svc.run(1, tau=2)
            chaos = ChaosController(svc, events)
            t0 = time.time()
            m = chaos.run(phases, tau=2)
            dt = time.time() - t0
            st = m["transport"]
    flaps = m["fleet_epoch"]
    retries = st["retries"]
    goodput = st["sends"]
    # the chaos layer must actually have fired — a zero here means the
    # benchmark silently stopped exercising the retry/flap machinery
    assert flaps >= 2, f"fleet never flapped (epoch={flaps})"
    assert retries > 0, f"faulty transport never retried: {st}"
    assert np.isfinite(m["mean_loss"])
    delta_pct = 100.0 * abs(m["mean_loss"] - stable_loss) / stable_loss
    return {"name": "fleet_flapping_faulty",
            "us_per_call": dt / phases * 1e6,
            "wall_s_per_phase": dt / phases, "phases": phases,
            "mean_loss": m["mean_loss"], "loss_delta_pct": delta_pct,
            "fleet_epoch": flaps, "goodput_sends": goodput,
            "retries": retries,
            "retry_overhead": retries / max(goodput, 1),
            "drops": st["drops"], "dups": st["dups"],
            "corruptions": st["corruptions"],
            "checksum_rejects": st["checksum_rejects"]}


def run(quick: bool = True):
    s = common.setup(quick)
    ds = _dataset(s)
    phases = 4 if quick else 8
    dcfg = DiPaCoConfig(levels=(2, 2), inner_steps=2, comm_dtype="int8")
    # one telemetry plane across all three fleets: the whole chaos run
    # (phases, fragment sends, retries, membership epochs) lands in a
    # single Perfetto-exportable timeline (CI uploads it)
    with common.make_telemetry("fleet") as tel:
        stable = _stable_row(s, ds, dcfg, phases, tel)
        rows = [stable,
                _loss30_row(s, ds, dcfg, phases, stable["mean_loss"],
                            tel),
                _flapping_row(s, ds, dcfg, phases, stable["mean_loss"],
                              tel)]
    common.record_bench("elastic_fleet", rows,
                        path=common.BENCH_TRAIN_PATH, trace=tel.path)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
