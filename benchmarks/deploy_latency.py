"""Deployment-plane latency: outer-update -> serving-visible staleness
and the serving throughput dip during a hot swap.

One process runs the whole pipeline the deployment plane connects: a
``TrainingService`` advances outer phases (writing per-module checkpoint
rows), a ``Publisher`` cuts + canary-gates + promotes candidate
manifests, and a ``ContinuousBatchingEngine`` serving a steady request
load hot-swaps to each promoted version between decode ticks.

Measured (recorded to ``BENCH_deploy.json``):

* ``staleness_s`` — wall-clock from the last module row of an outer
  phase landing in the checkpoint DB to the first engine tick that
  serves the new version (includes manifest cut, content-addressed
  copy, canary scoring, promote, and the engine's swap install);
* ``canary_ms`` / ``publish_ms`` — the canary-gate share vs the whole
  publish cycle;
* ``swap_tick_ratio`` — slowest tick in the swap window over the median
  steady-state tick (the throughput dip a drain-policy swap causes);
* ``install_ms`` — the parameter-install (restack with donation) cost.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.data import SyntheticCorpus, shard_documents
from repro.deploy import CanaryGate, DeploymentRegistry, Publisher
from repro.infra import TrainingService
from repro.models import api
from repro.models.config import DiPaCoConfig
from repro.serving import (ContinuousBatchingEngine, EngineOptions,
                           Request, prefix_hash_router)

from .common import BENCH_DEPLOY_PATH, record_bench


def _drive(engine, reqs, tick_times=None):
    """Submit ``reqs`` and tick the engine dry, timing each tick."""
    for r in reqs:
        engine.submit(r)
    fins = []
    while not engine.idle:
        t0 = time.perf_counter()
        fins.extend(engine.step(now=time.time()))
        jax.block_until_ready(engine.device_state())
        if tick_times is not None:
            tick_times.append(time.perf_counter() - t0)
    return fins


def run(quick: bool = True):
    cfg = get_smoke_config("dipaco-150m").replace(route_prefix_len=8)
    dcfg = DiPaCoConfig(levels=(2, 2), inner_steps=2)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, num_domains=4,
                             seq_len=48, seed=0)
    docs, doms = corpus.sample_documents(192, return_domains=True)
    ds = shard_documents(docs, doms % 4, 4)
    key = jax.random.PRNGKey(0)
    base, _ = api.init_model(key, cfg)
    shadow = corpus.sample_documents(8, seed=99)[:, :32]
    num_paths = 4
    max_new = 8
    n_load = 8 if quick else 24

    def make_reqs(seed, n):
        docs = corpus.sample_documents(n, seed=seed)
        return [Request(rid=seed * 1000 + i,
                        prompt=np.asarray(docs[i][:16], np.int32),
                        max_new=max_new, arrival=0.0) for i in range(n)]

    with tempfile.TemporaryDirectory() as root:
        svc = TrainingService(cfg, dcfg, ds, key=key,
                              ckpt_root=os.path.join(root, "db"),
                              base_params=base, batch_size=4,
                              peak_lr=1e-3, warmup=10, total_steps=100,
                              num_workers=1)
        registry = DeploymentRegistry(cfg, dcfg,
                                      os.path.join(root, "deploy"),
                                      key=key, base_params=base)
        # wide-open gate: this benchmark measures plumbing latency, not
        # model quality at miniature scale
        gate = CanaryGate(cfg, shadow, ppl_ratio_tol=100.0,
                          min_agreement=0.0)
        pub = Publisher(svc.db, registry, gate=gate)
        pub.bootstrap()

        engine = ContinuousBatchingEngine(
            cfg, options=EngineOptions(
                registry=registry, cache_len=32, slots_per_path=2,
                prefill_buckets=(16,), swap_policy="drain",
                route_fn=prefix_hash_router(num_paths)))
        engine.warmup()
        _drive(engine, make_reqs(1, n_load))        # warm the tick loop

        svc.run(1, tau=2)                           # phase 0 -> module rows
        t_update = max(r.ts for r in svc.db.rows(kind="module"))
        v0 = engine.version
        t0 = time.perf_counter()
        out = pub.publish_cycle()
        publish_s = time.perf_counter() - t0
        assert out["promoted"] is not None, f"no promotion: {out}"
        # staleness: outer update committed -> first tick serving it
        engine.submit(make_reqs(2, 1)[0])
        while engine.version == v0:
            engine.step(now=time.time())
        t_visible = time.time()
        staleness_s = t_visible - t_update
        while not engine.idle:
            engine.step(now=time.time())
        # canary share of the cycle: re-evaluate on the warmed gate
        t0 = time.perf_counter()
        gate.evaluate(registry.materialize(out["promoted"]),
                      registry.serving_paths())
        canary_s = time.perf_counter() - t0

        # steady-state ticks on the promoted version
        steady: list = []
        _drive(engine, make_reqs(3, n_load), steady)
        v_first = engine.version

        # next phase: measure the swap window under load
        svc.run(1, tau=2)
        pub.publish_cycle()
        swap_win: list = []
        fins = _drive(engine, make_reqs(4, n_load), swap_win)
        assert engine.version > v_first, "engine did not pick up the swap"
        assert any(f.version == engine.version for f in fins)
        # isolate the pure install cost (restack with donated buffers)
        t0 = time.perf_counter()
        engine._install(engine.version,
                        registry.materialize(engine.version))
        jax.block_until_ready(
            jax.tree_util.tree_leaves(engine._stacked_params)
            if engine.stacked else [])
        install_s = time.perf_counter() - t0
        svc.shutdown()
        pub.close()

    med_steady = float(np.median(steady))
    rows = [
        {"name": "deploy_staleness", "us_per_call": staleness_s * 1e6,
         "staleness_s": staleness_s, "publish_ms": publish_s * 1e3,
         "canary_ms": canary_s * 1e3,
         "versions": len(registry.versions)},
        {"name": "deploy_swap", "us_per_call": install_s * 1e6,
         "install_ms": install_s * 1e3,
         "swap_tick_ratio": float(max(swap_win) / med_steady),
         "steady_tick_ms": med_steady * 1e3,
         "swaps": engine.swaps},
    ]
    record_bench("deploy_latency", rows, path=BENCH_DEPLOY_PATH)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
